//! Pure-Rust twins of the Layer-1 kernels (`python/compile/kernels/ref.py`):
//! Fourier time encoding, fused message + GRU/RNN memory update, and
//! single-head temporal attention — forward *and* analytic backward.
//!
//! All math runs in `f64` (the `f32` cast happens at the backend interface),
//! which keeps the checked-in golden fixtures — generated from the float64
//! JAX reference — reproducible to ~1e-9 and makes gradient checks sharp.
//! The derivation is validated against `jax.value_and_grad` by
//! `python/tools/check_native_math.py`; this file is its transcription.
//! Under the `simd` cargo feature the matmul entry points dispatch to f32
//! lane kernels (`super::tensor`), loosening the fixture contract to 1e-4
//! relative — the non-GEMM math here stays f64 either way.
//!
//! Tensors are flat row-major `&[f64]` slices; shapes travel in [`Dims`].
//! Every kernel draws its outputs and temporaries from the caller's
//! [`Workspace`] arena (see [`super::tensor`]) and the forward caches can be
//! [`MsgCache::recycle`]d/[`AttnCache::recycle`]d back into it, so a warm
//! train step allocates nothing. Backward functions return per-weight
//! gradients (workspace buffers) in the forward weight order, which the
//! model layer accumulates into the flat gradient vector by manifest offset
//! before giving the buffers back.

use anyhow::{anyhow, Result};

use super::tensor::{
    f32_compute, load32, matmul_a_bt_into, matmul_at_b_into, matmul_into, Workspace,
};

/// Static shape bundle for one step.
#[derive(Debug, Clone, Copy)]
pub struct Dims {
    /// Batch rows.
    pub b: usize,
    /// Node memory/state dim.
    pub d: usize,
    /// Edge feature dim.
    pub de: usize,
    /// Time-encoding dim.
    pub td: usize,
    /// Message dim.
    pub dm: usize,
    /// Attention head dim.
    pub dh: usize,
    /// Neighbors per node.
    pub k: usize,
}

impl Dims {
    /// Message input dim: concat([s_self, s_other, phi, e_feat]).
    pub fn mi(&self) -> usize {
        2 * self.d + self.td + self.de
    }

    /// Attention key/value input dim: concat([nbr_state, phi, nbr_feat]).
    pub fn kv(&self) -> usize {
        self.d + self.td + self.de
    }
}

/// Memory-update cell kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdKind {
    Gru,
    Rnn,
}

impl UpdKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "gru" => Ok(UpdKind::Gru),
            "rnn" => Ok(UpdKind::Rnn),
            other => Err(anyhow!("unknown update kind {other:?}")),
        }
    }
}

// -- scalar helpers --------------------------------------------------------

#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable ln(1 + e^x).
#[inline]
pub fn softplus(x: f64) -> f64 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

// -- dense helpers ---------------------------------------------------------

/// In place: X[m,n] += bias[n] per row.
pub fn add_bias(x: &mut [f64], bias: &[f64], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    for i in 0..m {
        for (xj, &bj) in x[i * n..(i + 1) * n].iter_mut().zip(bias) {
            *xj += bj;
        }
    }
}

/// Column sums of X[m,n] into `out[n]` — the bias gradient.
pub fn col_sum_into(x: &[f64], m: usize, n: usize, out: &mut [f64]) {
    debug_assert_eq!(out.len(), n);
    out.fill(0.0);
    for i in 0..m {
        for (oj, &xj) in out.iter_mut().zip(&x[i * n..(i + 1) * n]) {
            *oj += xj;
        }
    }
}

// -- Fourier time encoding -------------------------------------------------

/// Phi(dt)[i, j] = cos(log1p(max(dt_i, 0)) · w_j + b_j)  — TGAT-style,
/// written into `out[len(dt), td]`. Dispatches to the f32 lane path under
/// the active f32 policy (`simd` feature), else the exact f64 path —
/// same dispatch idiom as the GEMM entry points in `tensor.rs`.
pub fn time_encode_into(dt: &[f64], w_t: &[f64], b_t: &[f64], out: &mut [f64], ws: &Workspace) {
    if f32_compute() {
        time_encode_into_f32(dt, w_t, b_t, out, ws);
        return;
    }
    time_encode_into_f64(dt, w_t, b_t, out);
}

/// Exact f64 reference path — the only path with `simd` off, whose bytes
/// invariant 9 pins. The backward pass stays f64 unconditionally.
pub fn time_encode_into_f64(dt: &[f64], w_t: &[f64], b_t: &[f64], out: &mut [f64]) {
    let td = w_t.len();
    debug_assert_eq!(out.len(), dt.len() * td);
    for (i, &dti) in dt.iter().enumerate() {
        let u = dti.max(0.0).ln_1p();
        let row = &mut out[i * td..(i + 1) * td];
        for ((o, &w), &bb) in row.iter_mut().zip(w_t).zip(b_t) {
            *o = (u * w + bb).cos();
        }
    }
}

/// f32 lane path: narrow `w_t`/`b_t` once per call, compute each row's
/// phase and cosine in f32 (`cos` is the serial-profile cost at small
/// shapes; the f32 call is the win), widen on store. `log1p` stays f64 —
/// one call per row over the full dt range. The f32 phase rounding is
/// ≲1e-6 at TIG time scales, well inside invariant 9's 1e-4 golden
/// tolerance (asserted by the golden fixtures under `--features simd`).
fn time_encode_into_f32(dt: &[f64], w_t: &[f64], b_t: &[f64], out: &mut [f64], ws: &Workspace) {
    let td = w_t.len();
    debug_assert_eq!(out.len(), dt.len() * td);
    let mut w32 = ws.take32_full(td);
    load32(&mut w32, w_t);
    let mut b32 = ws.take32_full(td);
    load32(&mut b32, b_t);
    for (i, &dti) in dt.iter().enumerate() {
        let u = dti.max(0.0).ln_1p() as f32;
        let row = &mut out[i * td..(i + 1) * td];
        for ((o, &w), &bb) in row.iter_mut().zip(&w32).zip(&b32) {
            *o = f64::from((u * w + bb).cos());
        }
    }
    ws.give32(b32);
    ws.give32(w32);
}

/// Accumulate d(loss)/d(w_t), d(loss)/d(b_t) given d(loss)/d(Phi).
pub fn time_encode_bwd(
    dt: &[f64],
    w_t: &[f64],
    b_t: &[f64],
    d_phi: &[f64],
    gw: &mut [f64],
    gb: &mut [f64],
) {
    let td = w_t.len();
    debug_assert_eq!(d_phi.len(), dt.len() * td);
    for (i, &dti) in dt.iter().enumerate() {
        let u = dti.max(0.0).ln_1p();
        let drow = &d_phi[i * td..(i + 1) * td];
        for (((gwj, gbj), (&w, &bb)), &dp) in
            gw.iter_mut().zip(gb.iter_mut()).zip(w_t.iter().zip(b_t)).zip(drow)
        {
            let s = -(u * w + bb).sin() * dp;
            *gwj += s * u;
            *gbj += s;
        }
    }
}

// -- fused message + memory update ----------------------------------------

/// Everything the backward pass needs from one forward call (all fields
/// are workspace buffers; call [`MsgCache::recycle`] when done).
pub struct MsgCache {
    dt: Vec<f64>,
    x: Vec<f64>,
    m: Vec<f64>,
    s_self: Vec<f64>,
    // GRU gates / RNN pre-activation output.
    z: Vec<f64>,
    r: Vec<f64>,
    h: Vec<f64>,
    out: Vec<f64>,
}

impl MsgCache {
    /// Return every cached buffer to the workspace.
    pub fn recycle(self, ws: &Workspace) {
        ws.give(self.dt);
        ws.give(self.x);
        ws.give(self.m);
        ws.give(self.s_self);
        ws.give(self.z);
        ws.give(self.r);
        ws.give(self.h);
        ws.give(self.out);
    }
}

/// Weight order (matches `ref_fused_msg_update` and the manifest layout):
/// GRU: `[w_t, b_t, Wm, bm, Wz, Uz, bz, Wr, Ur, br, Wh, Uh, bh]` (13)
/// RNN: `[w_t, b_t, Wm, bm, W, U, b]` (7)
///
/// `m = relu([s_self | s_other | Phi(dt) | e] · Wm + bm)`; GRU
/// `s' = (1-z)·s + z·h` with gates from `(m, s)`; RNN
/// `s' = tanh(m·W + s·U + b)`. Returns `(s' [B,d], cache)`.
#[allow(clippy::too_many_arguments)]
pub fn msg_update(
    kind: UpdKind,
    dims: &Dims,
    s_self: &[f64],
    s_other: &[f64],
    efeat: &[f64],
    dt: &[f64],
    w: &[&[f64]],
    ws: &Workspace,
) -> (Vec<f64>, MsgCache) {
    let (b, d, de, td, dm, mi) = (dims.b, dims.d, dims.de, dims.td, dims.dm, dims.mi());
    let (w_t, b_t, wm, bm) = (w[0], w[1], w[2], w[3]);
    // take_full: every element below is written before any read.
    let mut phi = ws.take_full(b * td);
    time_encode_into(dt, w_t, b_t, &mut phi, ws);

    let mut x = ws.take_full(b * mi);
    for i in 0..b {
        let row = &mut x[i * mi..(i + 1) * mi];
        row[..d].copy_from_slice(&s_self[i * d..(i + 1) * d]);
        row[d..2 * d].copy_from_slice(&s_other[i * d..(i + 1) * d]);
        row[2 * d..2 * d + td].copy_from_slice(&phi[i * td..(i + 1) * td]);
        row[2 * d + td..].copy_from_slice(&efeat[i * de..(i + 1) * de]);
    }
    ws.give(phi);
    let mut m = ws.take_full(b * dm);
    matmul_into(&x, wm, b, mi, dm, &mut m, ws);
    add_bias(&mut m, bm, b, dm);
    for v in m.iter_mut() {
        *v = v.max(0.0);
    }

    let mut cache = MsgCache {
        dt: ws.take_copy(dt),
        x,
        m,
        s_self: ws.take_copy(s_self),
        z: Vec::new(),
        r: Vec::new(),
        h: Vec::new(),
        out: Vec::new(),
    };

    match kind {
        UpdKind::Gru => {
            let (wz, uz, bz) = (w[4], w[5], w[6]);
            let (wr, ur, br) = (w[7], w[8], w[9]);
            let (wh, uh, bh) = (w[10], w[11], w[12]);
            let mut tmp = ws.take(b * d);

            let mut z = ws.take(b * d);
            matmul_into(&cache.m, wz, b, dm, d, &mut z, ws);
            matmul_into(s_self, uz, b, d, d, &mut tmp, ws);
            for (a, &s) in z.iter_mut().zip(tmp.iter()) {
                *a += s;
            }
            add_bias(&mut z, bz, b, d);
            for v in z.iter_mut() {
                *v = sigmoid(*v);
            }

            let mut r = ws.take(b * d);
            matmul_into(&cache.m, wr, b, dm, d, &mut r, ws);
            matmul_into(s_self, ur, b, d, d, &mut tmp, ws);
            for (a, &s) in r.iter_mut().zip(tmp.iter()) {
                *a += s;
            }
            add_bias(&mut r, br, b, d);
            for v in r.iter_mut() {
                *v = sigmoid(*v);
            }

            let mut rs = ws.take(b * d);
            for ((o, &ri), &si) in rs.iter_mut().zip(r.iter()).zip(s_self) {
                *o = ri * si;
            }
            let mut h = ws.take(b * d);
            matmul_into(&cache.m, wh, b, dm, d, &mut h, ws);
            matmul_into(&rs, uh, b, d, d, &mut tmp, ws);
            for (a, &s) in h.iter_mut().zip(tmp.iter()) {
                *a += s;
            }
            add_bias(&mut h, bh, b, d);
            for v in h.iter_mut() {
                *v = v.tanh();
            }
            ws.give(rs);
            ws.give(tmp);

            let mut out = ws.take(b * d);
            for (((o, &zi), &hi), &si) in
                out.iter_mut().zip(z.iter()).zip(h.iter()).zip(s_self)
            {
                *o = (1.0 - zi) * si + zi * hi;
            }
            cache.z = z;
            cache.r = r;
            cache.h = h;
            (out, cache)
        }
        UpdKind::Rnn => {
            let (ww, uu, bb) = (w[4], w[5], w[6]);
            let mut a = ws.take(b * d);
            matmul_into(&cache.m, ww, b, dm, d, &mut a, ws);
            let mut su = ws.take(b * d);
            matmul_into(s_self, uu, b, d, d, &mut su, ws);
            for (ai, &s) in a.iter_mut().zip(su.iter()) {
                *ai += s;
            }
            ws.give(su);
            add_bias(&mut a, bb, b, d);
            for v in a.iter_mut() {
                *v = v.tanh();
            }
            let out = ws.take_copy(&a);
            cache.out = a;
            (out, cache)
        }
    }
}

/// Gradients wrt every weight (forward order) given d(loss)/d(s').
/// Returned buffers come from `ws`; give them back after accumulating.
pub fn msg_update_bwd(
    kind: UpdKind,
    dims: &Dims,
    w: &[&[f64]],
    cache: &MsgCache,
    d_out: &[f64],
    ws: &Workspace,
) -> Vec<Vec<f64>> {
    let (b, d, td, dm, mi) = (dims.b, dims.d, dims.td, dims.dm, dims.mi());
    let (w_t, b_t, wm) = (w[0], w[1], w[2]);
    let (m, s, x) = (&cache.m, &cache.s_self, &cache.x);

    let mut grads: Vec<Vec<f64>> = Vec::with_capacity(w.len());
    let d_m: Vec<f64>;
    let mut tail: Vec<Vec<f64>> = Vec::new();

    match kind {
        UpdKind::Gru => {
            let (wz, wr) = (w[4], w[7]);
            let (wh, uh) = (w[10], w[11]);
            let (z, r, h) = (&cache.z, &cache.r, &cache.h);
            let mut rs = ws.take(b * d);
            for ((o, &ri), &si) in rs.iter_mut().zip(r.iter()).zip(s.iter()) {
                *o = ri * si;
            }

            let mut d_ah = ws.take(b * d);
            for (((o, &dv), &zi), &hi) in
                d_ah.iter_mut().zip(d_out).zip(z.iter()).zip(h.iter())
            {
                *o = dv * zi * (1.0 - hi * hi);
            }
            let mut g_wh = ws.take(dm * d);
            matmul_at_b_into(m, &d_ah, b, dm, d, &mut g_wh, ws);
            let mut g_uh = ws.take(d * d);
            matmul_at_b_into(&rs, &d_ah, b, d, d, &mut g_uh, ws);
            let mut g_bh = ws.take(d);
            col_sum_into(&d_ah, b, d, &mut g_bh);
            let mut dm_acc = ws.take(b * dm);
            matmul_a_bt_into(&d_ah, wh, b, dm, d, &mut dm_acc, ws);
            let mut d_r = ws.take(b * d);
            matmul_a_bt_into(&d_ah, uh, b, d, d, &mut d_r, ws);
            for (v, &si) in d_r.iter_mut().zip(s.iter()) {
                *v *= si;
            }

            let mut d_az = ws.take(b * d);
            for ((((o, &dv), &hi), &si), &zi) in d_az
                .iter_mut()
                .zip(d_out)
                .zip(h.iter())
                .zip(s.iter())
                .zip(z.iter())
            {
                *o = dv * (hi - si) * zi * (1.0 - zi);
            }
            let mut g_wz = ws.take(dm * d);
            matmul_at_b_into(m, &d_az, b, dm, d, &mut g_wz, ws);
            let mut g_uz = ws.take(d * d);
            matmul_at_b_into(s, &d_az, b, d, d, &mut g_uz, ws);
            let mut g_bz = ws.take(d);
            col_sum_into(&d_az, b, d, &mut g_bz);
            let mut tmp = ws.take(b * dm);
            matmul_a_bt_into(&d_az, wz, b, dm, d, &mut tmp, ws);
            for (acc, &v) in dm_acc.iter_mut().zip(tmp.iter()) {
                *acc += v;
            }

            let mut d_ar = ws.take(b * d);
            for ((o, &dv), &ri) in d_ar.iter_mut().zip(d_r.iter()).zip(r.iter()) {
                *o = dv * ri * (1.0 - ri);
            }
            let mut g_wr = ws.take(dm * d);
            matmul_at_b_into(m, &d_ar, b, dm, d, &mut g_wr, ws);
            let mut g_ur = ws.take(d * d);
            matmul_at_b_into(s, &d_ar, b, d, d, &mut g_ur, ws);
            let mut g_br = ws.take(d);
            col_sum_into(&d_ar, b, d, &mut g_br);
            matmul_a_bt_into(&d_ar, wr, b, dm, d, &mut tmp, ws);
            for (acc, &v) in dm_acc.iter_mut().zip(tmp.iter()) {
                *acc += v;
            }

            ws.give(tmp);
            ws.give(rs);
            ws.give(d_ah);
            ws.give(d_az);
            ws.give(d_ar);
            ws.give(d_r);
            d_m = dm_acc;
            tail.extend([g_wz, g_uz, g_bz, g_wr, g_ur, g_br, g_wh, g_uh, g_bh]);
        }
        UpdKind::Rnn => {
            let ww = w[4];
            let out = &cache.out;
            let mut d_a = ws.take(b * d);
            for ((o, &dv), &oi) in d_a.iter_mut().zip(d_out).zip(out.iter()) {
                *o = dv * (1.0 - oi * oi);
            }
            let mut g_w = ws.take(dm * d);
            matmul_at_b_into(m, &d_a, b, dm, d, &mut g_w, ws);
            let mut g_u = ws.take(d * d);
            matmul_at_b_into(s, &d_a, b, d, d, &mut g_u, ws);
            let mut g_b = ws.take(d);
            col_sum_into(&d_a, b, d, &mut g_b);
            let mut dm_buf = ws.take(b * dm);
            matmul_a_bt_into(&d_a, ww, b, dm, d, &mut dm_buf, ws);
            ws.give(d_a);
            d_m = dm_buf;
            tail.extend([g_w, g_u, g_b]);
        }
    }

    // Shared message/feature stage.
    let mut d_mpre = ws.take(b * dm);
    for ((o, &dv), &mv) in d_mpre.iter_mut().zip(d_m.iter()).zip(m.iter()) {
        *o = if mv > 0.0 { dv } else { 0.0 };
    }
    ws.give(d_m);
    let mut g_wm = ws.take(mi * dm);
    matmul_at_b_into(x, &d_mpre, b, mi, dm, &mut g_wm, ws);
    let mut g_bm = ws.take(dm);
    col_sum_into(&d_mpre, b, dm, &mut g_bm);
    let mut d_x = ws.take(b * mi);
    matmul_a_bt_into(&d_mpre, wm, b, mi, dm, &mut d_x, ws);
    ws.give(d_mpre);
    let mut d_phi = ws.take(b * td);
    for i in 0..b {
        d_phi[i * td..(i + 1) * td]
            .copy_from_slice(&d_x[i * mi + 2 * d..i * mi + 2 * d + td]);
    }
    ws.give(d_x);
    let mut g_wt = ws.take(td);
    let mut g_bt = ws.take(td);
    time_encode_bwd(&cache.dt, w_t, b_t, &d_phi, &mut g_wt, &mut g_bt);
    ws.give(d_phi);

    grads.push(g_wt);
    grads.push(g_bt);
    grads.push(g_wm);
    grads.push(g_bm);
    grads.extend(tail);
    grads
}

// -- temporal attention ----------------------------------------------------

/// Forward intermediates for the backward pass (workspace buffers; call
/// [`AttnCache::recycle`] when done).
pub struct AttnCache {
    nbr_dt: Vec<f64>,
    qin: Vec<f64>,
    q: Vec<f64>,
    kvin: Vec<f64>,
    key: Vec<f64>,
    val: Vec<f64>,
    attn: Vec<f64>,
    has: Vec<f64>,
    cat: Vec<f64>,
    out: Vec<f64>,
}

impl AttnCache {
    /// Return every cached buffer to the workspace.
    pub fn recycle(self, ws: &Workspace) {
        ws.give(self.nbr_dt);
        ws.give(self.qin);
        ws.give(self.q);
        ws.give(self.kvin);
        ws.give(self.key);
        ws.give(self.val);
        ws.give(self.attn);
        ws.give(self.has);
        ws.give(self.cat);
        ws.give(self.out);
    }
}

/// Row-parallel driver of the fused masked-softmax + context stage of
/// [`attention`]: rows are independent and each is computed identically
/// regardless of the chunking, so splitting them across threads (with the
/// same spawn policy as the matmuls) cannot change any row's bits.
#[allow(clippy::too_many_arguments)]
fn attn_softmax_ctx(
    dims: &Dims,
    q: &[f64],
    key: &[f64],
    val: &[f64],
    q_state: &[f64],
    nbr_mask: &[f64],
    attn: &mut [f64],
    has: &mut [f64],
    cat: &mut [f64],
) {
    #[cfg(feature = "parallel")]
    {
        let (b, d, dh, k) = (dims.b, dims.d, dims.dh, dims.k);
        let nt = super::tensor::plan_split(b, b * k * (2 * dh + d));
        if nt > 1 {
            let rows = b.div_ceil(nt);
            std::thread::scope(|s| {
                for (ci, ((ac, hc), cc)) in attn
                    .chunks_mut(rows * k)
                    .zip(has.chunks_mut(rows))
                    .zip(cat.chunks_mut(rows * (d + dh)))
                    .enumerate()
                {
                    s.spawn(move || {
                        attn_softmax_ctx_rows(
                            dims, ci * rows, q, key, val, q_state, nbr_mask, ac, hc, cc,
                        );
                    });
                }
            });
            return;
        }
    }
    attn_softmax_ctx_rows(dims, 0, q, key, val, q_state, nbr_mask, attn, has, cat);
}

/// Fused masked-softmax + context over global rows `[i0, i0 + rows)`
/// (`rows` = `has_chunk.len()`): pass 1 computes the masked scores with a
/// running max (the same `f64::max` left fold the separate max pass
/// performed), pass 2 exponentiates and sums, and the normalization folds
/// into the context accumulation. Every operand and fold order matches
/// the unfused form, so the per-row results are bit-identical to it.
#[allow(clippy::too_many_arguments)]
fn attn_softmax_ctx_rows(
    dims: &Dims,
    i0: usize,
    q: &[f64],
    key: &[f64],
    val: &[f64],
    q_state: &[f64],
    nbr_mask: &[f64],
    attn: &mut [f64],
    has: &mut [f64],
    cat: &mut [f64],
) {
    let (d, dh, k) = (dims.d, dims.dh, dims.k);
    let scale = 1.0 / (dh as f64).sqrt();
    for (r, hasi) in has.iter_mut().enumerate() {
        let i = i0 + r;
        let qrow = &q[i * dh..(i + 1) * dh];
        let srow = &mut attn[r * k..(r + 1) * k];
        let mut mx = f64::NEG_INFINITY;
        for (slot, sj) in srow.iter_mut().enumerate() {
            let krow = &key[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            let dot: f64 = qrow.iter().zip(krow).map(|(&a, &c)| a * c).sum();
            *sj = dot * scale + (nbr_mask[i * k + slot] - 1.0) * 1e9;
            mx = mx.max(*sj);
        }
        let mut denom = 0.0;
        for sj in srow.iter_mut() {
            *sj = (*sj - mx).exp();
            denom += *sj;
        }
        let msum: f64 = nbr_mask[i * k..(i + 1) * k].iter().sum();
        *hasi = if msum > 0.0 { 1.0 } else { 0.0 };

        let crow = &mut cat[r * (d + dh)..(r + 1) * (d + dh)];
        crow[..d].copy_from_slice(&q_state[i * d..(i + 1) * d]);
        let ctx = &mut crow[d..];
        let h = *hasi;
        for slot in 0..k {
            let an = srow[slot] / denom;
            srow[slot] = an;
            let a = an * h;
            if a == 0.0 {
                continue;
            }
            let vrow = &val[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            for (cj, &vj) in ctx.iter_mut().zip(vrow) {
                *cj += a * vj;
            }
        }
    }
}

/// Weight order: `[w_t, b_t, Wq, Wk, Wv, Wo, bo]`.
///
/// Single-head attention over the K most-recent temporal neighbors
/// (see `ref_temporal_attention`): rows with no valid neighbor get their
/// context zeroed. Returns `(emb [B,d], cache)`.
#[allow(clippy::too_many_arguments)]
pub fn attention(
    dims: &Dims,
    q_state: &[f64],
    nbr_state: &[f64],
    nbr_feat: &[f64],
    nbr_dt: &[f64],
    nbr_mask: &[f64],
    w: &[&[f64]],
    ws: &Workspace,
) -> (Vec<f64>, AttnCache) {
    let (b, d, de, td, dh, k) = (dims.b, dims.d, dims.de, dims.td, dims.dh, dims.k);
    let kv = dims.kv();
    let (w_t, b_t, wq, wk, wv, wo, bo) = (w[0], w[1], w[2], w[3], w[4], w[5], w[6]);

    // Query: [s | Phi(0)] · Wq. (take_full buffers are fully overwritten
    // before any read; `zeros` must stay the zero-filled take.)
    let zeros = ws.take(b);
    let mut phi0 = ws.take_full(b * td);
    time_encode_into(&zeros, w_t, b_t, &mut phi0, ws);
    ws.give(zeros);
    let mut qin = ws.take_full(b * (d + td));
    for i in 0..b {
        let row = &mut qin[i * (d + td)..(i + 1) * (d + td)];
        row[..d].copy_from_slice(&q_state[i * d..(i + 1) * d]);
        row[d..].copy_from_slice(&phi0[i * td..(i + 1) * td]);
    }
    ws.give(phi0);
    let mut q = ws.take_full(b * dh);
    matmul_into(&qin, wq, b, d + td, dh, &mut q, ws);

    // Keys/values over B·K flattened neighbor rows.
    let bk = b * k;
    let mut phin = ws.take_full(bk * td);
    time_encode_into(nbr_dt, w_t, b_t, &mut phin, ws);
    let mut kvin = ws.take_full(bk * kv);
    for i in 0..bk {
        let row = &mut kvin[i * kv..(i + 1) * kv];
        row[..d].copy_from_slice(&nbr_state[i * d..(i + 1) * d]);
        row[d..d + td].copy_from_slice(&phin[i * td..(i + 1) * td]);
        row[d + td..].copy_from_slice(&nbr_feat[i * de..(i + 1) * de]);
    }
    ws.give(phin);
    let mut key = ws.take_full(bk * dh);
    matmul_into(&kvin, wk, bk, kv, dh, &mut key, ws);
    let mut val = ws.take_full(bk * dh);
    matmul_into(&kvin, wv, bk, kv, dh, &mut val, ws);

    // Fused masked softmax + context: one row walk computes scores and
    // their running max, one exponentiates and sums, and the softmax
    // normalization folds into the context accumulation — bit-identical
    // per row to the unfused three-pass form (the fold order and every
    // operand are unchanged), minus two full walks over the score matrix.
    // `cat` must stay the zero-filled take: context rows accumulate.
    let mut attn = ws.take_full(bk);
    let mut has = ws.take_full(b);
    let mut cat = ws.take(b * (d + dh));
    attn_softmax_ctx(dims, &q, &key, &val, q_state, nbr_mask, &mut attn, &mut has, &mut cat);
    let mut o = ws.take(b * d);
    matmul_into(&cat, wo, b, d + dh, d, &mut o, ws);
    add_bias(&mut o, bo, b, d);
    for v in o.iter_mut() {
        *v = v.max(0.0);
    }

    let out = ws.take_copy(&o);
    let cache = AttnCache {
        nbr_dt: ws.take_copy(nbr_dt),
        qin,
        q,
        kvin,
        key,
        val,
        attn,
        has,
        cat,
        out: o,
    };
    (out, cache)
}

/// `(weight grads in forward order, d(loss)/d(q_state))`, all buffers
/// drawn from `ws`.
pub fn attention_bwd(
    dims: &Dims,
    w: &[&[f64]],
    cache: &AttnCache,
    d_out: &[f64],
    ws: &Workspace,
) -> (Vec<Vec<f64>>, Vec<f64>) {
    let (b, d, td, dh, k) = (dims.b, dims.d, dims.td, dims.dh, dims.k);
    let kv = dims.kv();
    let bk = b * k;
    let (w_t, b_t, wq, wk, wv, wo) = (w[0], w[1], w[2], w[3], w[4], w[5]);

    let mut d_opre = ws.take(b * d);
    for ((o, &dv), &ov) in d_opre.iter_mut().zip(d_out).zip(cache.out.iter()) {
        *o = if ov > 0.0 { dv } else { 0.0 };
    }
    let mut g_wo = ws.take((d + dh) * d);
    matmul_at_b_into(&cache.cat, &d_opre, b, d + dh, d, &mut g_wo, ws);
    let mut g_bo = ws.take(d);
    col_sum_into(&d_opre, b, d, &mut g_bo);
    let mut d_cat = ws.take(b * (d + dh));
    matmul_a_bt_into(&d_opre, wo, b, d + dh, d, &mut d_cat, ws);
    ws.give(d_opre);

    let mut d_s = ws.take(b * d);
    let mut d_q = ws.take(b * dh);
    let mut d_key = ws.take(bk * dh);
    let mut d_val = ws.take(bk * dh);
    let mut d_ctx = ws.take(dh);
    let mut d_attn = ws.take(k);
    let scale = 1.0 / (dh as f64).sqrt();

    for i in 0..b {
        let crow = &d_cat[i * (d + dh)..(i + 1) * (d + dh)];
        d_s[i * d..(i + 1) * d].copy_from_slice(&crow[..d]);
        // d_ctx with the has-neighbor zeroing folded in.
        let hasi = cache.has[i];
        for (o, &v) in d_ctx.iter_mut().zip(&crow[d..]) {
            *o = v * hasi;
        }

        // Softmax backward.
        let arow = &cache.attn[i * k..(i + 1) * k];
        for (slot, da) in d_attn.iter_mut().enumerate() {
            let vrow = &cache.val[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            *da = d_ctx.iter().zip(vrow).map(|(&x, &y)| x * y).sum();
            let dvrow = &mut d_val[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            for (dv, &x) in dvrow.iter_mut().zip(d_ctx.iter()) {
                *dv = arow[slot] * x;
            }
        }
        let dot: f64 = arow.iter().zip(d_attn.iter()).map(|(&a, &da)| a * da).sum();
        let qrow = &cache.q[i * dh..(i + 1) * dh];
        let dqrow = &mut d_q[i * dh..(i + 1) * dh];
        for slot in 0..k {
            let d_sc = arow[slot] * (d_attn[slot] - dot) * scale;
            if d_sc == 0.0 {
                continue;
            }
            let krow = &cache.key[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            for (dq, &kj) in dqrow.iter_mut().zip(krow) {
                *dq += d_sc * kj;
            }
            let dkrow = &mut d_key[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            for (dk, &qj) in dkrow.iter_mut().zip(qrow) {
                *dk += d_sc * qj;
            }
        }
    }
    ws.give(d_ctx);
    ws.give(d_attn);
    ws.give(d_cat);

    // Query projection.
    let mut g_wq = ws.take((d + td) * dh);
    matmul_at_b_into(&cache.qin, &d_q, b, d + td, dh, &mut g_wq, ws);
    let mut d_qin = ws.take(b * (d + td));
    matmul_a_bt_into(&d_q, wq, b, d + td, dh, &mut d_qin, ws);
    ws.give(d_q);
    let mut g_wt = ws.take(td);
    let mut g_bt = ws.take(td);
    {
        let mut d_phi0 = ws.take(b * td);
        for i in 0..b {
            d_phi0[i * td..(i + 1) * td]
                .copy_from_slice(&d_qin[i * (d + td) + d..(i + 1) * (d + td)]);
        }
        // dt = 0 for the query encoding: only b_t receives gradient.
        let zeros = ws.take(b);
        time_encode_bwd(&zeros, w_t, b_t, &d_phi0, &mut g_wt, &mut g_bt);
        ws.give(zeros);
        ws.give(d_phi0);
        for i in 0..b {
            for (ds, &dq) in d_s[i * d..(i + 1) * d]
                .iter_mut()
                .zip(&d_qin[i * (d + td)..i * (d + td) + d])
            {
                *ds += dq;
            }
        }
    }
    ws.give(d_qin);

    // Key/value projections.
    let mut g_wk = ws.take(kv * dh);
    matmul_at_b_into(&cache.kvin, &d_key, bk, kv, dh, &mut g_wk, ws);
    let mut g_wv = ws.take(kv * dh);
    matmul_at_b_into(&cache.kvin, &d_val, bk, kv, dh, &mut g_wv, ws);
    let mut d_kvin = ws.take(bk * kv);
    matmul_a_bt_into(&d_key, wk, bk, kv, dh, &mut d_kvin, ws);
    let mut tmp = ws.take(bk * kv);
    matmul_a_bt_into(&d_val, wv, bk, kv, dh, &mut tmp, ws);
    for (acc, &v) in d_kvin.iter_mut().zip(tmp.iter()) {
        *acc += v;
    }
    ws.give(tmp);
    ws.give(d_key);
    ws.give(d_val);
    let mut d_phin = ws.take(bk * td);
    for i in 0..bk {
        d_phin[i * td..(i + 1) * td]
            .copy_from_slice(&d_kvin[i * kv + d..i * kv + d + td]);
    }
    ws.give(d_kvin);
    time_encode_bwd(&cache.nbr_dt, w_t, b_t, &d_phin, &mut g_wt, &mut g_bt);
    ws.give(d_phin);

    (vec![g_wt, g_bt, g_wq, g_wk, g_wv, g_wo, g_bo], d_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::tensor::{matmul, matmul_at_b};

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a);
    }

    #[test]
    fn matmul_transposes_agree() {
        // (AᵀB)ᵀ == BᵀA — checked elementwise via the two kernels.
        // The simd build runs the f32 lane path through the same entry
        // points, so the tolerance follows the compute precision.
        let tol = if cfg!(feature = "simd") { 1e-5 } else { 1e-12 };
        let a = vec![1.0, -2.0, 0.5, 3.0, 2.0, -1.0]; // [3,2]
        let b = vec![0.3, 1.0, -0.7, 0.2, 0.9, -0.4]; // [3,2]
        let atb = matmul_at_b(&a, &b, 3, 2, 2); // [2,2]
        let bta = matmul_at_b(&b, &a, 3, 2, 2); // [2,2]
        for i in 0..2 {
            for j in 0..2 {
                assert!((atb[i * 2 + j] - bta[j * 2 + i]).abs() < tol);
            }
        }
    }

    #[test]
    fn softplus_and_sigmoid_are_stable() {
        assert!(softplus(1000.0).is_finite());
        assert!(softplus(-1000.0) >= 0.0);
        assert!((softplus(0.0) - (2.0f64).ln()).abs() < 1e-12);
        assert!(sigmoid(1000.0) <= 1.0);
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn time_encode_at_zero_is_cos_bias() {
        let w = vec![1.0, 0.5];
        let b = vec![0.0, 0.3];
        let mut phi = vec![0.0; 2];
        time_encode_into_f64(&[0.0], &w, &b, &mut phi);
        assert!((phi[0] - 1.0).abs() < 1e-12);
        assert!((phi[1] - 0.3f64.cos()).abs() < 1e-12);
    }

    /// Central-difference gradient check of the fused update (both kinds).
    /// f64-only: central differences at eps=1e-6 need the exact path, and
    /// the analytic/numeric agreement it proves is feature-independent.
    #[cfg(not(feature = "simd"))]
    #[test]
    fn msg_update_gradcheck() {
        let dims = Dims { b: 3, d: 2, de: 2, td: 2, dm: 3, dh: 2, k: 2 };
        let mut state = 42u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64 / (1u64 << 31) as f64) - 0.5
        };
        let rand_vec = |n: usize, next: &mut dyn FnMut() -> f64| -> Vec<f64> {
            (0..n).map(|_| next()).collect()
        };
        let s_self = rand_vec(dims.b * dims.d, &mut next);
        let s_other = rand_vec(dims.b * dims.d, &mut next);
        let efeat = rand_vec(dims.b * dims.de, &mut next);
        let dt = vec![0.5, 2.0, 7.0];
        let ws = Workspace::new();

        for kind in [UpdKind::Gru, UpdKind::Rnn] {
            let shapes: Vec<usize> = match kind {
                UpdKind::Gru => vec![
                    dims.td, dims.td, dims.mi() * dims.dm, dims.dm,
                    dims.dm * dims.d, dims.d * dims.d, dims.d,
                    dims.dm * dims.d, dims.d * dims.d, dims.d,
                    dims.dm * dims.d, dims.d * dims.d, dims.d,
                ],
                UpdKind::Rnn => vec![
                    dims.td, dims.td, dims.mi() * dims.dm, dims.dm,
                    dims.dm * dims.d, dims.d * dims.d, dims.d,
                ],
            };
            let mut weights: Vec<Vec<f64>> =
                shapes.iter().map(|&n| rand_vec(n, &mut next)).collect();
            let loss = |ws: &Workspace, weights: &[Vec<f64>]| -> f64 {
                let refs: Vec<&[f64]> = weights.iter().map(|v| v.as_slice()).collect();
                let (out, cache) =
                    msg_update(kind, &dims, &s_self, &s_other, &efeat, &dt, &refs, ws);
                let l: f64 = out.iter().sum();
                cache.recycle(ws);
                ws.give(out);
                l
            };
            let refs: Vec<&[f64]> = weights.iter().map(|v| v.as_slice()).collect();
            let (out, cache) =
                msg_update(kind, &dims, &s_self, &s_other, &efeat, &dt, &refs, &ws);
            let d_out = vec![1.0; out.len()];
            let grads = msg_update_bwd(kind, &dims, &refs, &cache, &d_out, &ws);
            cache.recycle(&ws);
            ws.give(out);
            drop(refs);

            let eps = 1e-6;
            for wi in 0..weights.len() {
                for j in 0..weights[wi].len() {
                    let orig = weights[wi][j];
                    weights[wi][j] = orig + eps;
                    let up = loss(&ws, &weights);
                    weights[wi][j] = orig - eps;
                    let dn = loss(&ws, &weights);
                    weights[wi][j] = orig;
                    let num = (up - dn) / (2.0 * eps);
                    let ana = grads[wi][j];
                    assert!(
                        (num - ana).abs() < 1e-5 * (1.0 + num.abs().max(ana.abs())),
                        "{kind:?} w{wi}[{j}]: numeric {num} vs analytic {ana}"
                    );
                }
            }
            for g in grads {
                ws.give(g);
            }
        }
    }

    /// Central-difference gradient check of the attention kernel.
    /// f64-only, like `msg_update_gradcheck`.
    #[cfg(not(feature = "simd"))]
    #[test]
    fn attention_gradcheck() {
        let dims = Dims { b: 3, d: 2, de: 2, td: 2, dm: 3, dh: 2, k: 2 };
        let mut state = 7u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64 / (1u64 << 31) as f64) - 0.5
        };
        let rand_vec = |n: usize, next: &mut dyn FnMut() -> f64| -> Vec<f64> {
            (0..n).map(|_| next()).collect()
        };
        let q_state = rand_vec(dims.b * dims.d, &mut next);
        let nbr_state = rand_vec(dims.b * dims.k * dims.d, &mut next);
        let nbr_feat = rand_vec(dims.b * dims.k * dims.de, &mut next);
        let nbr_dt = vec![0.5, 2.0, 7.0, 1.0, 0.0, 3.0];
        // Row 0 fully masked (has_nbr = 0), row 1 partially, row 2 full.
        let nbr_mask = vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0];
        let ws = Workspace::new();

        let shapes: Vec<usize> = vec![
            dims.td, dims.td,
            (dims.d + dims.td) * dims.dh,
            dims.kv() * dims.dh,
            dims.kv() * dims.dh,
            (dims.d + dims.dh) * dims.d,
            dims.d,
        ];
        let mut weights: Vec<Vec<f64>> =
            shapes.iter().map(|&n| rand_vec(n, &mut next)).collect();
        let loss = |ws: &Workspace, weights: &[Vec<f64>]| -> f64 {
            let refs: Vec<&[f64]> = weights.iter().map(|v| v.as_slice()).collect();
            let (out, cache) =
                attention(&dims, &q_state, &nbr_state, &nbr_feat, &nbr_dt, &nbr_mask, &refs, ws);
            let l: f64 = out.iter().sum();
            cache.recycle(ws);
            ws.give(out);
            l
        };
        let refs: Vec<&[f64]> = weights.iter().map(|v| v.as_slice()).collect();
        let (out, cache) =
            attention(&dims, &q_state, &nbr_state, &nbr_feat, &nbr_dt, &nbr_mask, &refs, &ws);
        let d_out = vec![1.0; out.len()];
        let (grads, d_s) = attention_bwd(&dims, &refs, &cache, &d_out, &ws);
        cache.recycle(&ws);
        ws.give(out);
        ws.give(d_s);
        drop(refs);

        let eps = 1e-6;
        for wi in 0..weights.len() {
            for j in 0..weights[wi].len() {
                let orig = weights[wi][j];
                weights[wi][j] = orig + eps;
                let up = loss(&ws, &weights);
                weights[wi][j] = orig - eps;
                let dn = loss(&ws, &weights);
                weights[wi][j] = orig;
                let num = (up - dn) / (2.0 * eps);
                let ana = grads[wi][j];
                assert!(
                    (num - ana).abs() < 1e-5 * (1.0 + num.abs().max(ana.abs())),
                    "attn w{wi}[{j}]: numeric {num} vs analytic {ana}"
                );
            }
        }
    }

    /// A warm workspace makes the fused-update kernel allocation-free:
    /// every take after the first round is served from the pool.
    #[test]
    fn kernels_are_alloc_free_when_warm() {
        let dims = Dims { b: 4, d: 3, de: 2, td: 2, dm: 3, dh: 2, k: 2 };
        let ws = Workspace::new();
        let s_self = vec![0.1; dims.b * dims.d];
        let s_other = vec![0.2; dims.b * dims.d];
        let efeat = vec![0.3; dims.b * dims.de];
        let dt = vec![1.0; dims.b];
        let shapes = [
            dims.td, dims.td, dims.mi() * dims.dm, dims.dm,
            dims.dm * dims.d, dims.d * dims.d, dims.d,
        ];
        let weights: Vec<Vec<f64>> = shapes.iter().map(|&n| vec![0.05; n]).collect();
        let refs: Vec<&[f64]> = weights.iter().map(|v| v.as_slice()).collect();
        let round = |ws: &Workspace| {
            let (out, cache) =
                msg_update(UpdKind::Rnn, &dims, &s_self, &s_other, &efeat, &dt, &refs, ws);
            let d_out = vec![1.0; out.len()];
            let grads = msg_update_bwd(UpdKind::Rnn, &dims, &refs, &cache, &d_out, ws);
            for g in grads {
                ws.give(g);
            }
            cache.recycle(ws);
            ws.give(out);
        };
        round(&ws);
        let warm = ws.pooled();
        round(&ws);
        assert_eq!(
            ws.pooled(),
            warm,
            "second round must recycle every buffer instead of allocating"
        );
    }
}
