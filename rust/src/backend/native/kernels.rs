//! Pure-Rust twins of the Layer-1 kernels (`python/compile/kernels/ref.py`):
//! Fourier time encoding, fused message + GRU/RNN memory update, and
//! single-head temporal attention — forward *and* analytic backward.
//!
//! All math runs in `f64` (the `f32` cast happens at the backend interface),
//! which keeps the checked-in golden fixtures — generated from the float64
//! JAX reference — reproducible to ~1e-12 and makes gradient checks sharp.
//! The derivation is validated against `jax.value_and_grad` by
//! `python/tools/check_native_math.py`; this file is its transcription.
//!
//! Tensors are flat row-major `&[f64]` slices; shapes travel in [`Dims`].
//! Backward functions return freshly allocated per-weight gradients in the
//! same order as the forward weight list, which the model layer accumulates
//! into the flat gradient vector by manifest offset.

use anyhow::{anyhow, Result};

/// Static shape bundle for one step.
#[derive(Debug, Clone, Copy)]
pub struct Dims {
    /// Batch rows.
    pub b: usize,
    /// Node memory/state dim.
    pub d: usize,
    /// Edge feature dim.
    pub de: usize,
    /// Time-encoding dim.
    pub td: usize,
    /// Message dim.
    pub dm: usize,
    /// Attention head dim.
    pub dh: usize,
    /// Neighbors per node.
    pub k: usize,
}

impl Dims {
    /// Message input dim: concat([s_self, s_other, phi, e_feat]).
    pub fn mi(&self) -> usize {
        2 * self.d + self.td + self.de
    }

    /// Attention key/value input dim: concat([nbr_state, phi, nbr_feat]).
    pub fn kv(&self) -> usize {
        self.d + self.td + self.de
    }
}

/// Memory-update cell kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdKind {
    Gru,
    Rnn,
}

impl UpdKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "gru" => Ok(UpdKind::Gru),
            "rnn" => Ok(UpdKind::Rnn),
            other => Err(anyhow!("unknown update kind {other:?}")),
        }
    }
}

// -- scalar helpers --------------------------------------------------------

#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable ln(1 + e^x).
#[inline]
pub fn softplus(x: f64) -> f64 {
    x.max(0.0) + (-x.abs()).exp().ln_1p()
}

// -- dense primitives ------------------------------------------------------

/// C[m,n] = A[m,k] · B[k,n].
pub fn matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &aip) in arow.iter().enumerate() {
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow) {
                *cj += aip * bj;
            }
        }
    }
    c
}

/// C[k,n] = Aᵀ · B with A[m,k], B[m,n] — the weight-gradient contraction.
pub fn matmul_at_b(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    let mut c = vec![0.0; k * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (p, &aip) in arow.iter().enumerate() {
            if aip == 0.0 {
                continue;
            }
            let crow = &mut c[p * n..(p + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow) {
                *cj += aip * bj;
            }
        }
    }
    c
}

/// C[m,k] = A · Bᵀ with A[m,n], B[k,n] — the input-gradient contraction.
pub fn matmul_a_bt(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for (p, cp) in crow.iter_mut().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            *cp = arow.iter().zip(brow).map(|(&x, &y)| x * y).sum();
        }
    }
    c
}

/// In place: X[m,n] += bias[n] per row.
pub fn add_bias(x: &mut [f64], bias: &[f64], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    for i in 0..m {
        for (xj, &bj) in x[i * n..(i + 1) * n].iter_mut().zip(bias) {
            *xj += bj;
        }
    }
}

/// Column sums of X[m,n] — the bias gradient.
pub fn col_sum(x: &[f64], m: usize, n: usize) -> Vec<f64> {
    let mut out = vec![0.0; n];
    for i in 0..m {
        for (oj, &xj) in out.iter_mut().zip(&x[i * n..(i + 1) * n]) {
            *oj += xj;
        }
    }
    out
}

// -- Fourier time encoding -------------------------------------------------

/// Phi(dt)[i, j] = cos(log1p(max(dt_i, 0)) · w_j + b_j)  — TGAT-style.
pub fn time_encode(dt: &[f64], w_t: &[f64], b_t: &[f64]) -> Vec<f64> {
    let td = w_t.len();
    let mut out = vec![0.0; dt.len() * td];
    for (i, &dti) in dt.iter().enumerate() {
        let u = dti.max(0.0).ln_1p();
        let row = &mut out[i * td..(i + 1) * td];
        for ((o, &w), &bb) in row.iter_mut().zip(w_t).zip(b_t) {
            *o = (u * w + bb).cos();
        }
    }
    out
}

/// Accumulate d(loss)/d(w_t), d(loss)/d(b_t) given d(loss)/d(Phi).
pub fn time_encode_bwd(
    dt: &[f64],
    w_t: &[f64],
    b_t: &[f64],
    d_phi: &[f64],
    gw: &mut [f64],
    gb: &mut [f64],
) {
    let td = w_t.len();
    debug_assert_eq!(d_phi.len(), dt.len() * td);
    for (i, &dti) in dt.iter().enumerate() {
        let u = dti.max(0.0).ln_1p();
        let drow = &d_phi[i * td..(i + 1) * td];
        for (((gwj, gbj), (&w, &bb)), &dp) in
            gw.iter_mut().zip(gb.iter_mut()).zip(w_t.iter().zip(b_t)).zip(drow)
        {
            let s = -(u * w + bb).sin() * dp;
            *gwj += s * u;
            *gbj += s;
        }
    }
}

// -- fused message + memory update ----------------------------------------

/// Everything the backward pass needs from one forward call.
pub struct MsgCache {
    dt: Vec<f64>,
    x: Vec<f64>,
    m: Vec<f64>,
    s_self: Vec<f64>,
    // GRU gates / RNN pre-activation output.
    z: Vec<f64>,
    r: Vec<f64>,
    h: Vec<f64>,
    out: Vec<f64>,
}

/// Weight order (matches `ref_fused_msg_update` and the manifest layout):
/// GRU: `[w_t, b_t, Wm, bm, Wz, Uz, bz, Wr, Ur, br, Wh, Uh, bh]` (13)
/// RNN: `[w_t, b_t, Wm, bm, W, U, b]` (7)
///
/// `m = relu([s_self | s_other | Phi(dt) | e] · Wm + bm)`; GRU
/// `s' = (1-z)·s + z·h` with gates from `(m, s)`; RNN
/// `s' = tanh(m·W + s·U + b)`. Returns `(s' [B,d], cache)`.
pub fn msg_update(
    kind: UpdKind,
    dims: &Dims,
    s_self: &[f64],
    s_other: &[f64],
    efeat: &[f64],
    dt: &[f64],
    w: &[&[f64]],
) -> (Vec<f64>, MsgCache) {
    let (b, d, de, td, dm, mi) = (dims.b, dims.d, dims.de, dims.td, dims.dm, dims.mi());
    let (w_t, b_t, wm, bm) = (w[0], w[1], w[2], w[3]);
    let phi = time_encode(dt, w_t, b_t);

    let mut x = vec![0.0; b * mi];
    for i in 0..b {
        let row = &mut x[i * mi..(i + 1) * mi];
        row[..d].copy_from_slice(&s_self[i * d..(i + 1) * d]);
        row[d..2 * d].copy_from_slice(&s_other[i * d..(i + 1) * d]);
        row[2 * d..2 * d + td].copy_from_slice(&phi[i * td..(i + 1) * td]);
        row[2 * d + td..].copy_from_slice(&efeat[i * de..(i + 1) * de]);
    }
    let mut m = matmul(&x, wm, b, mi, dm);
    add_bias(&mut m, bm, b, dm);
    for v in m.iter_mut() {
        *v = v.max(0.0);
    }

    let mut cache = MsgCache {
        dt: dt.to_vec(),
        x,
        m,
        s_self: s_self.to_vec(),
        z: Vec::new(),
        r: Vec::new(),
        h: Vec::new(),
        out: Vec::new(),
    };

    match kind {
        UpdKind::Gru => {
            let (wz, uz, bz) = (w[4], w[5], w[6]);
            let (wr, ur, br) = (w[7], w[8], w[9]);
            let (wh, uh, bh) = (w[10], w[11], w[12]);
            let mut az = matmul(&cache.m, wz, b, dm, d);
            let sz = matmul(s_self, uz, b, d, d);
            for (a, s) in az.iter_mut().zip(&sz) {
                *a += s;
            }
            add_bias(&mut az, bz, b, d);
            let z: Vec<f64> = az.iter().map(|&a| sigmoid(a)).collect();

            let mut ar = matmul(&cache.m, wr, b, dm, d);
            let sr = matmul(s_self, ur, b, d, d);
            for (a, s) in ar.iter_mut().zip(&sr) {
                *a += s;
            }
            add_bias(&mut ar, br, b, d);
            let r: Vec<f64> = ar.iter().map(|&a| sigmoid(a)).collect();

            let rs: Vec<f64> = r.iter().zip(s_self).map(|(&ri, &si)| ri * si).collect();
            let mut ah = matmul(&cache.m, wh, b, dm, d);
            let sh = matmul(&rs, uh, b, d, d);
            for (a, s) in ah.iter_mut().zip(&sh) {
                *a += s;
            }
            add_bias(&mut ah, bh, b, d);
            let h: Vec<f64> = ah.iter().map(|&a| a.tanh()).collect();

            let out: Vec<f64> = z
                .iter()
                .zip(&h)
                .zip(s_self)
                .map(|((&zi, &hi), &si)| (1.0 - zi) * si + zi * hi)
                .collect();
            cache.z = z;
            cache.r = r;
            cache.h = h;
            (out, cache)
        }
        UpdKind::Rnn => {
            let (ww, uu, bb) = (w[4], w[5], w[6]);
            let mut a = matmul(&cache.m, ww, b, dm, d);
            let su = matmul(s_self, uu, b, d, d);
            for (ai, s) in a.iter_mut().zip(&su) {
                *ai += s;
            }
            add_bias(&mut a, bb, b, d);
            let out: Vec<f64> = a.iter().map(|&ai| ai.tanh()).collect();
            cache.out = out.clone();
            (out, cache)
        }
    }
}

/// Gradients wrt every weight (forward order) given d(loss)/d(s').
pub fn msg_update_bwd(
    kind: UpdKind,
    dims: &Dims,
    w: &[&[f64]],
    cache: &MsgCache,
    d_out: &[f64],
) -> Vec<Vec<f64>> {
    let (b, d, td, dm, mi) = (dims.b, dims.d, dims.td, dims.dm, dims.mi());
    let (w_t, b_t, wm) = (w[0], w[1], w[2]);
    let (m, s, x) = (&cache.m, &cache.s_self, &cache.x);

    let mut grads: Vec<Vec<f64>> = Vec::with_capacity(w.len());
    let d_m: Vec<f64>;
    let mut tail: Vec<Vec<f64>> = Vec::new();

    match kind {
        UpdKind::Gru => {
            let (wz, wr) = (w[4], w[7]);
            let (wh, uh) = (w[10], w[11]);
            let (z, r, h) = (&cache.z, &cache.r, &cache.h);
            let rs: Vec<f64> = r.iter().zip(s).map(|(&ri, &si)| ri * si).collect();

            let d_ah: Vec<f64> = d_out
                .iter()
                .zip(z)
                .zip(h)
                .map(|((&dv, &zi), &hi)| dv * zi * (1.0 - hi * hi))
                .collect();
            let g_wh = matmul_at_b(m, &d_ah, b, dm, d);
            let g_uh = matmul_at_b(&rs, &d_ah, b, d, d);
            let g_bh = col_sum(&d_ah, b, d);
            let mut dm_acc = matmul_a_bt(&d_ah, wh, b, dm, d);
            let d_r: Vec<f64> = matmul_a_bt(&d_ah, uh, b, d, d)
                .iter()
                .zip(s)
                .map(|(&v, &si)| v * si)
                .collect();

            let d_az: Vec<f64> = d_out
                .iter()
                .zip(h)
                .zip(s)
                .zip(z)
                .map(|(((&dv, &hi), &si), &zi)| dv * (hi - si) * zi * (1.0 - zi))
                .collect();
            let g_wz = matmul_at_b(m, &d_az, b, dm, d);
            let g_uz = matmul_at_b(s, &d_az, b, d, d);
            let g_bz = col_sum(&d_az, b, d);
            for (acc, v) in dm_acc.iter_mut().zip(matmul_a_bt(&d_az, wz, b, dm, d)) {
                *acc += v;
            }

            let d_ar: Vec<f64> = d_r
                .iter()
                .zip(r)
                .map(|(&dv, &ri)| dv * ri * (1.0 - ri))
                .collect();
            let g_wr = matmul_at_b(m, &d_ar, b, dm, d);
            let g_ur = matmul_at_b(s, &d_ar, b, d, d);
            let g_br = col_sum(&d_ar, b, d);
            for (acc, v) in dm_acc.iter_mut().zip(matmul_a_bt(&d_ar, wr, b, dm, d)) {
                *acc += v;
            }

            d_m = dm_acc;
            tail.extend([g_wz, g_uz, g_bz, g_wr, g_ur, g_br, g_wh, g_uh, g_bh]);
        }
        UpdKind::Rnn => {
            let ww = w[4];
            let out = &cache.out;
            let d_a: Vec<f64> = d_out
                .iter()
                .zip(out)
                .map(|(&dv, &oi)| dv * (1.0 - oi * oi))
                .collect();
            let g_w = matmul_at_b(m, &d_a, b, dm, d);
            let g_u = matmul_at_b(s, &d_a, b, d, d);
            let g_b = col_sum(&d_a, b, d);
            d_m = matmul_a_bt(&d_a, ww, b, dm, d);
            tail.extend([g_w, g_u, g_b]);
        }
    }

    // Shared message/feature stage.
    let d_mpre: Vec<f64> = d_m
        .iter()
        .zip(m)
        .map(|(&dv, &mv)| if mv > 0.0 { dv } else { 0.0 })
        .collect();
    let g_wm = matmul_at_b(x, &d_mpre, b, mi, dm);
    let g_bm = col_sum(&d_mpre, b, dm);
    let d_x = matmul_a_bt(&d_mpre, wm, b, mi, dm);
    let mut d_phi = vec![0.0; b * td];
    for i in 0..b {
        d_phi[i * td..(i + 1) * td]
            .copy_from_slice(&d_x[i * mi + 2 * d..i * mi + 2 * d + td]);
    }
    let mut g_wt = vec![0.0; td];
    let mut g_bt = vec![0.0; td];
    time_encode_bwd(&cache.dt, w_t, b_t, &d_phi, &mut g_wt, &mut g_bt);

    grads.push(g_wt);
    grads.push(g_bt);
    grads.push(g_wm);
    grads.push(g_bm);
    grads.extend(tail);
    grads
}

// -- temporal attention ----------------------------------------------------

/// Forward intermediates for the backward pass.
pub struct AttnCache {
    nbr_dt: Vec<f64>,
    qin: Vec<f64>,
    q: Vec<f64>,
    kvin: Vec<f64>,
    key: Vec<f64>,
    val: Vec<f64>,
    attn: Vec<f64>,
    has: Vec<f64>,
    cat: Vec<f64>,
    out: Vec<f64>,
}

/// Weight order: `[w_t, b_t, Wq, Wk, Wv, Wo, bo]`.
///
/// Single-head attention over the K most-recent temporal neighbors
/// (see `ref_temporal_attention`): rows with no valid neighbor get their
/// context zeroed. Returns `(emb [B,d], cache)`.
pub fn attention(
    dims: &Dims,
    q_state: &[f64],
    nbr_state: &[f64],
    nbr_feat: &[f64],
    nbr_dt: &[f64],
    nbr_mask: &[f64],
    w: &[&[f64]],
) -> (Vec<f64>, AttnCache) {
    let (b, d, de, td, dh, k) = (dims.b, dims.d, dims.de, dims.td, dims.dh, dims.k);
    let kv = dims.kv();
    let (w_t, b_t, wq, wk, wv, wo, bo) = (w[0], w[1], w[2], w[3], w[4], w[5], w[6]);

    // Query: [s | Phi(0)] · Wq.
    let phi0 = time_encode(&vec![0.0; b], w_t, b_t);
    let mut qin = vec![0.0; b * (d + td)];
    for i in 0..b {
        let row = &mut qin[i * (d + td)..(i + 1) * (d + td)];
        row[..d].copy_from_slice(&q_state[i * d..(i + 1) * d]);
        row[d..].copy_from_slice(&phi0[i * td..(i + 1) * td]);
    }
    let q = matmul(&qin, wq, b, d + td, dh);

    // Keys/values over B·K flattened neighbor rows.
    let bk = b * k;
    let phin = time_encode(nbr_dt, w_t, b_t);
    let mut kvin = vec![0.0; bk * kv];
    for i in 0..bk {
        let row = &mut kvin[i * kv..(i + 1) * kv];
        row[..d].copy_from_slice(&nbr_state[i * d..(i + 1) * d]);
        row[d..d + td].copy_from_slice(&phin[i * td..(i + 1) * td]);
        row[d + td..].copy_from_slice(&nbr_feat[i * de..(i + 1) * de]);
    }
    let key = matmul(&kvin, wk, bk, kv, dh);
    let val = matmul(&kvin, wv, bk, kv, dh);

    // Masked softmax scores.
    let scale = 1.0 / (dh as f64).sqrt();
    let mut attn = vec![0.0; bk];
    let mut has = vec![0.0; b];
    for i in 0..b {
        let qrow = &q[i * dh..(i + 1) * dh];
        let srow = &mut attn[i * k..(i + 1) * k];
        for (slot, sj) in srow.iter_mut().enumerate() {
            let krow = &key[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            let dot: f64 = qrow.iter().zip(krow).map(|(&a, &c)| a * c).sum();
            *sj = dot * scale + (nbr_mask[i * k + slot] - 1.0) * 1e9;
        }
        let mx = srow.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut denom = 0.0;
        for sj in srow.iter_mut() {
            *sj = (*sj - mx).exp();
            denom += *sj;
        }
        for sj in srow.iter_mut() {
            *sj /= denom;
        }
        let msum: f64 = nbr_mask[i * k..(i + 1) * k].iter().sum();
        has[i] = if msum > 0.0 { 1.0 } else { 0.0 };
    }

    // Context + output projection.
    let mut cat = vec![0.0; b * (d + dh)];
    for i in 0..b {
        let row = &mut cat[i * (d + dh)..(i + 1) * (d + dh)];
        row[..d].copy_from_slice(&q_state[i * d..(i + 1) * d]);
        let ctx = &mut row[d..];
        for slot in 0..k {
            let a = attn[i * k + slot] * has[i];
            if a == 0.0 {
                continue;
            }
            let vrow = &val[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            for (cj, &vj) in ctx.iter_mut().zip(vrow) {
                *cj += a * vj;
            }
        }
    }
    let mut o = matmul(&cat, wo, b, d + dh, d);
    add_bias(&mut o, bo, b, d);
    for v in o.iter_mut() {
        *v = v.max(0.0);
    }

    let cache = AttnCache {
        nbr_dt: nbr_dt.to_vec(),
        qin,
        q,
        kvin,
        key,
        val,
        attn,
        has,
        cat,
        out: o.clone(),
    };
    (o, cache)
}

/// `(weight grads in forward order, d(loss)/d(q_state))`.
pub fn attention_bwd(
    dims: &Dims,
    w: &[&[f64]],
    cache: &AttnCache,
    d_out: &[f64],
) -> (Vec<Vec<f64>>, Vec<f64>) {
    let (b, d, td, dh, k) = (dims.b, dims.d, dims.td, dims.dh, dims.k);
    let kv = dims.kv();
    let bk = b * k;
    let (w_t, b_t, wq, wk, wv, wo) = (w[0], w[1], w[2], w[3], w[4], w[5]);

    let d_opre: Vec<f64> = d_out
        .iter()
        .zip(&cache.out)
        .map(|(&dv, &ov)| if ov > 0.0 { dv } else { 0.0 })
        .collect();
    let g_wo = matmul_at_b(&cache.cat, &d_opre, b, d + dh, d);
    let g_bo = col_sum(&d_opre, b, d);
    let d_cat = matmul_a_bt(&d_opre, wo, b, d + dh, d);

    let mut d_s = vec![0.0; b * d];
    let mut d_q = vec![0.0; b * dh];
    let mut d_key = vec![0.0; bk * dh];
    let mut d_val = vec![0.0; bk * dh];
    let scale = 1.0 / (dh as f64).sqrt();

    for i in 0..b {
        let crow = &d_cat[i * (d + dh)..(i + 1) * (d + dh)];
        d_s[i * d..(i + 1) * d].copy_from_slice(&crow[..d]);
        // d_ctx with the has-neighbor zeroing folded in.
        let hasi = cache.has[i];
        let d_ctx: Vec<f64> = crow[d..].iter().map(|&v| v * hasi).collect();

        // Softmax backward.
        let arow = &cache.attn[i * k..(i + 1) * k];
        let mut d_attn = vec![0.0; k];
        for (slot, da) in d_attn.iter_mut().enumerate() {
            let vrow = &cache.val[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            *da = d_ctx.iter().zip(vrow).map(|(&x, &y)| x * y).sum();
            let dvrow = &mut d_val[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            for (dv, &x) in dvrow.iter_mut().zip(&d_ctx) {
                *dv = arow[slot] * x;
            }
        }
        let dot: f64 = arow.iter().zip(&d_attn).map(|(&a, &da)| a * da).sum();
        let qrow = &cache.q[i * dh..(i + 1) * dh];
        let dqrow = &mut d_q[i * dh..(i + 1) * dh];
        for slot in 0..k {
            let d_sc = arow[slot] * (d_attn[slot] - dot) * scale;
            if d_sc == 0.0 {
                continue;
            }
            let krow = &cache.key[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            for (dq, &kj) in dqrow.iter_mut().zip(krow) {
                *dq += d_sc * kj;
            }
            let dkrow = &mut d_key[(i * k + slot) * dh..(i * k + slot + 1) * dh];
            for (dk, &qj) in dkrow.iter_mut().zip(qrow) {
                *dk += d_sc * qj;
            }
        }
    }

    // Query projection.
    let g_wq = matmul_at_b(&cache.qin, &d_q, b, d + td, dh);
    let d_qin = matmul_a_bt(&d_q, wq, b, d + td, dh);
    let mut g_wt = vec![0.0; td];
    let mut g_bt = vec![0.0; td];
    {
        let mut d_phi0 = vec![0.0; b * td];
        for i in 0..b {
            d_phi0[i * td..(i + 1) * td]
                .copy_from_slice(&d_qin[i * (d + td) + d..(i + 1) * (d + td)]);
        }
        // dt = 0 for the query encoding: only b_t receives gradient.
        time_encode_bwd(&vec![0.0; b], w_t, b_t, &d_phi0, &mut g_wt, &mut g_bt);
        for i in 0..b {
            for (ds, &dq) in d_s[i * d..(i + 1) * d]
                .iter_mut()
                .zip(&d_qin[i * (d + td)..i * (d + td) + d])
            {
                *ds += dq;
            }
        }
    }

    // Key/value projections.
    let g_wk = matmul_at_b(&cache.kvin, &d_key, bk, kv, dh);
    let g_wv = matmul_at_b(&cache.kvin, &d_val, bk, kv, dh);
    let mut d_kvin = matmul_a_bt(&d_key, wk, bk, kv, dh);
    for (acc, v) in d_kvin.iter_mut().zip(matmul_a_bt(&d_val, wv, bk, kv, dh)) {
        *acc += v;
    }
    let mut d_phin = vec![0.0; bk * td];
    for i in 0..bk {
        d_phin[i * td..(i + 1) * td]
            .copy_from_slice(&d_kvin[i * kv + d..i * kv + d + td]);
    }
    time_encode_bwd(&cache.nbr_dt, w_t, b_t, &d_phin, &mut g_wt, &mut g_bt);

    (vec![g_wt, g_bt, g_wq, g_wk, g_wv, g_wo, g_bo], d_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a);
    }

    #[test]
    fn matmul_transposes_agree() {
        // (AᵀB)ᵀ == BᵀA — checked elementwise via the two kernels.
        let a = vec![1.0, -2.0, 0.5, 3.0, 2.0, -1.0]; // [3,2]
        let b = vec![0.3, 1.0, -0.7, 0.2, 0.9, -0.4]; // [3,2]
        let atb = matmul_at_b(&a, &b, 3, 2, 2); // [2,2]
        let bta = matmul_at_b(&b, &a, 3, 2, 2); // [2,2]
        for i in 0..2 {
            for j in 0..2 {
                assert!((atb[i * 2 + j] - bta[j * 2 + i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn softplus_and_sigmoid_are_stable() {
        assert!(softplus(1000.0).is_finite());
        assert!(softplus(-1000.0) >= 0.0);
        assert!((softplus(0.0) - (2.0f64).ln()).abs() < 1e-12);
        assert!(sigmoid(1000.0) <= 1.0);
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn time_encode_at_zero_is_cos_bias() {
        let w = vec![1.0, 0.5];
        let b = vec![0.0, 0.3];
        let phi = time_encode(&[0.0], &w, &b);
        assert!((phi[0] - 1.0).abs() < 1e-12);
        assert!((phi[1] - 0.3f64.cos()).abs() < 1e-12);
    }

    /// Central-difference gradient check of the fused update (both kinds).
    #[test]
    fn msg_update_gradcheck() {
        let dims = Dims { b: 3, d: 2, de: 2, td: 2, dm: 3, dh: 2, k: 2 };
        let mut state = 42u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64 / (1u64 << 31) as f64) - 0.5
        };
        let rand_vec = |n: usize, next: &mut dyn FnMut() -> f64| -> Vec<f64> {
            (0..n).map(|_| next()).collect()
        };
        let s_self = rand_vec(dims.b * dims.d, &mut next);
        let s_other = rand_vec(dims.b * dims.d, &mut next);
        let efeat = rand_vec(dims.b * dims.de, &mut next);
        let dt = vec![0.5, 2.0, 7.0];

        for kind in [UpdKind::Gru, UpdKind::Rnn] {
            let shapes: Vec<usize> = match kind {
                UpdKind::Gru => vec![
                    dims.td, dims.td, dims.mi() * dims.dm, dims.dm,
                    dims.dm * dims.d, dims.d * dims.d, dims.d,
                    dims.dm * dims.d, dims.d * dims.d, dims.d,
                    dims.dm * dims.d, dims.d * dims.d, dims.d,
                ],
                UpdKind::Rnn => vec![
                    dims.td, dims.td, dims.mi() * dims.dm, dims.dm,
                    dims.dm * dims.d, dims.d * dims.d, dims.d,
                ],
            };
            let mut weights: Vec<Vec<f64>> =
                shapes.iter().map(|&n| rand_vec(n, &mut next)).collect();
            let loss = |ws: &[Vec<f64>]| -> f64 {
                let refs: Vec<&[f64]> = ws.iter().map(|v| v.as_slice()).collect();
                let (out, _) = msg_update(kind, &dims, &s_self, &s_other, &efeat, &dt, &refs);
                out.iter().sum()
            };
            let refs: Vec<&[f64]> = weights.iter().map(|v| v.as_slice()).collect();
            let (out, cache) = msg_update(kind, &dims, &s_self, &s_other, &efeat, &dt, &refs);
            let d_out = vec![1.0; out.len()];
            let grads = msg_update_bwd(kind, &dims, &refs, &cache, &d_out);
            drop(refs);

            let eps = 1e-6;
            for wi in 0..weights.len() {
                for j in 0..weights[wi].len() {
                    let orig = weights[wi][j];
                    weights[wi][j] = orig + eps;
                    let up = loss(&weights);
                    weights[wi][j] = orig - eps;
                    let dn = loss(&weights);
                    weights[wi][j] = orig;
                    let num = (up - dn) / (2.0 * eps);
                    let ana = grads[wi][j];
                    assert!(
                        (num - ana).abs() < 1e-5 * (1.0 + num.abs().max(ana.abs())),
                        "{kind:?} w{wi}[{j}]: numeric {num} vs analytic {ana}"
                    );
                }
            }
        }
    }

    /// Central-difference gradient check of the attention kernel.
    #[test]
    fn attention_gradcheck() {
        let dims = Dims { b: 3, d: 2, de: 2, td: 2, dm: 3, dh: 2, k: 2 };
        let mut state = 7u64;
        let mut next = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64 / (1u64 << 31) as f64) - 0.5
        };
        let rand_vec = |n: usize, next: &mut dyn FnMut() -> f64| -> Vec<f64> {
            (0..n).map(|_| next()).collect()
        };
        let q_state = rand_vec(dims.b * dims.d, &mut next);
        let nbr_state = rand_vec(dims.b * dims.k * dims.d, &mut next);
        let nbr_feat = rand_vec(dims.b * dims.k * dims.de, &mut next);
        let nbr_dt = vec![0.5, 2.0, 7.0, 1.0, 0.0, 3.0];
        // Row 0 fully masked (has_nbr = 0), row 1 partially, row 2 full.
        let nbr_mask = vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0];

        let shapes: Vec<usize> = vec![
            dims.td, dims.td,
            (dims.d + dims.td) * dims.dh,
            dims.kv() * dims.dh,
            dims.kv() * dims.dh,
            (dims.d + dims.dh) * dims.d,
            dims.d,
        ];
        let mut weights: Vec<Vec<f64>> =
            shapes.iter().map(|&n| rand_vec(n, &mut next)).collect();
        let loss = |ws: &[Vec<f64>]| -> f64 {
            let refs: Vec<&[f64]> = ws.iter().map(|v| v.as_slice()).collect();
            let (out, _) =
                attention(&dims, &q_state, &nbr_state, &nbr_feat, &nbr_dt, &nbr_mask, &refs);
            out.iter().sum()
        };
        let refs: Vec<&[f64]> = weights.iter().map(|v| v.as_slice()).collect();
        let (out, cache) =
            attention(&dims, &q_state, &nbr_state, &nbr_feat, &nbr_dt, &nbr_mask, &refs);
        let d_out = vec![1.0; out.len()];
        let (grads, _) = attention_bwd(&dims, &refs, &cache, &d_out);
        drop(refs);

        let eps = 1e-6;
        for wi in 0..weights.len() {
            for j in 0..weights[wi].len() {
                let orig = weights[wi][j];
                weights[wi][j] = orig + eps;
                let up = loss(&weights);
                weights[wi][j] = orig - eps;
                let dn = loss(&weights);
                weights[wi][j] = orig;
                let num = (up - dn) / (2.0 * eps);
                let ana = grads[wi][j];
                assert!(
                    (num - ana).abs() < 1e-5 * (1.0 + num.abs().max(ana.abs())),
                    "attn w{wi}[{j}]: numeric {num} vs analytic {ana}"
                );
            }
        }
    }
}
