//! The full generalized TIG encoder-decoder step (Sec. II-C) on the native
//! kernels: Memory → Message → Update → (Restart) → Embed → Decode, its
//! BCE link-prediction loss, and the composed analytic backward pass.
//!
//! Semantics are identical to `python/compile/model.py::_forward` /
//! `make_train_step` / `make_eval_step` (minus the numerically irrelevant
//! `_touch` term that only pins the HLO signature): padded rows (mask 0)
//! contribute nothing to the loss and keep their input memory; negatives
//! never update memory. Verified end-to-end against `jax.value_and_grad`
//! fixtures in `rust/tests/golden.rs`.
//!
//! Perf layout: the model owns a [`Workspace`] arena plus persistent `f64`
//! mirrors of the f32 interface buffers, so a warm train step performs no
//! heap allocation; the two message/update roles and the three attention
//! roles (src/dst/neg) are independent and run concurrently under the
//! `parallel` cargo feature via [`tensor::join2`]/[`tensor::join3`]
//! (bit-identical to the serial schedule — the gradient accumulation
//! order into the flat vector never changes). Weight-sharing role pairs
//! are row-stacked into single GEMMs ([`decode_pair`] and the TIGE
//! restart branch) — per-row bit-identical to the separate calls they
//! replaced, and feeding the f32 lane kernels larger m under `simd`.

use anyhow::{anyhow, bail, Result};

use crate::backend::{
    BatchBuffers, EvalOut, ModelBackend, ModelEntry, ParamSpec, TrainOut, N_TENSORS,
    T_DST_DT_LAST, T_DST_MEM, T_DST_NBR, T_DT, T_EDGE_FEAT, T_MASK, T_NEG_DT_LAST,
    T_NEG_MEM, T_NEG_NBR, T_SRC_DT_LAST, T_SRC_MEM, T_SRC_NBR,
};

use super::kernels::{
    self, attention, attention_bwd, col_sum_into, msg_update, msg_update_bwd, sigmoid,
    softplus, time_encode_bwd, time_encode_into, AttnCache, Dims, MsgCache, UpdKind,
};
use super::tensor::{self, matmul_a_bt_into, matmul_at_b_into, matmul_into, Workspace};
use super::NativeConfig;

/// Manifest parameter names feeding the fused update kernel, in its weight
/// order (mirrors `python/compile/model.py::_update_weights`).
const MSG_GRU_WEIGHTS: [&str; 13] = [
    "msg/w_t", "msg/b_t", "msg/Wm", "msg/bm",
    "upd/Wz", "upd/Uz", "upd/bz",
    "upd/Wr", "upd/Ur", "upd/br",
    "upd/Wh", "upd/Uh", "upd/bh",
];
const MSG_RNN_WEIGHTS: [&str; 7] =
    ["msg/w_t", "msg/b_t", "msg/Wm", "msg/bm", "upd/W", "upd/U", "upd/b"];
/// Attention kernel weight order (`_attn_weights`).
const ATTN_WEIGHTS: [&str; 7] =
    ["att/w_t", "att/b_t", "att/Wq", "att/Wk", "att/Wv", "att/Wo", "att/bo"];

fn find<'a>(layout: &'a [ParamSpec], name: &str) -> Result<&'a ParamSpec> {
    layout
        .iter()
        .find(|p| p.name == name)
        .ok_or_else(|| anyhow!("param {name:?} not in layout"))
}

fn pslice<'a>(flat: &'a [f64], layout: &[ParamSpec], name: &str) -> Result<&'a [f64]> {
    let s = find(layout, name)?;
    Ok(&flat[s.offset..s.offset + s.elements()])
}

/// Resolve `names` into borrowed parameter slices, filling the leading
/// `names.len()` slots of `out`. A fixed-size caller buffer instead of a
/// returned `Vec` keeps this warm-step helper off the heap.
fn weight_refs_into<'a>(
    flat: &'a [f64],
    layout: &[ParamSpec],
    names: &[&str],
    out: &mut [&'a [f64]],
) -> Result<()> {
    debug_assert!(names.len() <= out.len());
    for (slot, n) in out.iter_mut().zip(names) {
        *slot = pslice(flat, layout, n)?;
    }
    Ok(())
}

fn add_grad(gflat: &mut [f64], layout: &[ParamSpec], name: &str, vals: &[f64]) -> Result<()> {
    let s = find(layout, name)?;
    if vals.len() != s.elements() {
        bail!("gradient size mismatch for {name:?}: {} != {}", vals.len(), s.elements());
    }
    for (g, &v) in gflat[s.offset..s.offset + s.elements()].iter_mut().zip(vals) {
        *g += v;
    }
    Ok(())
}

/// Refill `dst` with the f64 widening of `src`, reusing its capacity.
fn load_f64(dst: &mut Vec<f64>, src: &[f32]) {
    dst.clear();
    dst.extend(src.iter().map(|&x| x as f64));
}

/// Refill `dst` with the f32 narrowing of `src`, reusing its capacity.
fn write_f32(dst: &mut Vec<f32>, src: &[f64]) {
    dst.clear();
    dst.extend(src.iter().map(|&x| x as f32));
}

/// `dst = mask·new + (1-mask)·old`, rowwise, reusing `dst`'s capacity.
fn write_masked(dst: &mut Vec<f32>, new: &[f64], old: &[f64], mask: &[f64], b: usize, d: usize) {
    dst.clear();
    dst.resize(b * d, 0.0);
    for i in 0..b {
        let m = mask[i];
        for j in 0..d {
            dst[i * d + j] = (m * new[i * d + j] + (1.0 - m) * old[i * d + j]) as f32;
        }
    }
}

/// Cached restart-branch forward state (TIGE). All workspace buffers.
/// The src and dst roles share the restart weights, so their inputs and
/// branch activations are row-stacked (`x` is `[2b, mi]`, `rst` is
/// `[2b, d]`; rows `0..b` = src, `b..2b` = dst) and the branch runs as ONE
/// GEMM — per-row bit-identical to the two separate calls it replaced.
struct RestartCtx {
    gate: Vec<f64>,
    x: Vec<f64>,
    rst: Vec<f64>,
    upd_src: Vec<f64>,
    upd_dst: Vec<f64>,
}

impl RestartCtx {
    fn recycle(self, ws: &Workspace) {
        ws.give(self.gate);
        ws.give(self.x);
        ws.give(self.rst);
        ws.give(self.upd_src);
        ws.give(self.upd_dst);
    }
}

/// Cached embedding-module forward state.
enum EmbedCtx {
    Attn(Box<(AttnCache, AttnCache, AttnCache)>),
    Proj { u_src: Vec<f64>, u_dst: Vec<f64>, u_neg: Vec<f64> },
    Ident,
}

impl EmbedCtx {
    fn recycle(self, ws: &Workspace) {
        match self {
            EmbedCtx::Attn(caches) => {
                let (ca_s, ca_d, ca_n) = *caches;
                ca_s.recycle(ws);
                ca_d.recycle(ws);
                ca_n.recycle(ws);
            }
            EmbedCtx::Proj { u_src, u_dst, u_neg } => {
                ws.give(u_src);
                ws.give(u_dst);
                ws.give(u_neg);
            }
            EmbedCtx::Ident => {}
        }
    }
}

struct DecCache {
    cat: Vec<f64>,
    h: Vec<f64>,
}

impl DecCache {
    fn recycle(self, ws: &Workspace) {
        ws.give(self.cat);
        ws.give(self.h);
    }
}

/// Where one step's results land (caller-owned, buffers reused).
enum StepSink<'a> {
    Train(&'a mut TrainOut),
    Eval(&'a mut EvalOut),
}

/// Return every forward-pass buffer that outlives the embed/decode stages
/// to the workspace — the single place that guards the zero-alloc-per-step
/// invariant for both the eval early-return and the train tail.
#[allow(clippy::too_many_arguments)]
fn release_forward_state(
    ws: &Workspace,
    new_src: Vec<f64>,
    new_dst: Vec<f64>,
    emb_src: Vec<f64>,
    emb_dst: Vec<f64>,
    emb_neg: Vec<f64>,
    embed_ctx: EmbedCtx,
    restart: Option<RestartCtx>,
    cache_src: MsgCache,
    cache_dst: MsgCache,
) {
    ws.give(new_src);
    ws.give(new_dst);
    ws.give(emb_src);
    ws.give(emb_dst);
    ws.give(emb_neg);
    embed_ctx.recycle(ws);
    if let Some(ctx) = restart {
        ctx.recycle(ws);
    }
    cache_src.recycle(ws);
    cache_dst.recycle(ws);
}

/// Decoder MLP over BOTH role pairs in one GEMM per layer: rows `0..b` of
/// the stacked `cat` hold `[emb_src | emb_dst]` (the positive pair), rows
/// `b..2b` hold `[emb_src | emb_neg]`. The pairs share every decoder
/// weight, so row-stacking doubles the GEMM's m dimension for free, and
/// row-stacked matmul is per-row bit-identical to two separate calls
/// (asserted by `prop_row_stacked_matmul_is_bit_identical` in
/// tests/proptests.rs). Returns `(pos_logit, neg_logit, cache)`; the
/// backward pass consumes the stacked cache one half at a time so its
/// `AᵀB` block folds keep the seed's grouping (invariant 9).
fn decode_pair(
    layout: &[ParamSpec],
    dims: &Dims,
    flat: &[f64],
    src: &[f64],
    dst: &[f64],
    neg: &[f64],
    ws: &Workspace,
) -> Result<(Vec<f64>, Vec<f64>, DecCache)> {
    let (b, d) = (dims.b, dims.d);
    let w1 = pslice(flat, layout, "dec/W1")?;
    let b1 = pslice(flat, layout, "dec/b1")?;
    let w2 = pslice(flat, layout, "dec/W2")?;
    let bias2 = pslice(flat, layout, "dec/b2")?;
    // take_full: every row is fully written below.
    let mut cat = ws.take_full(2 * b * 2 * d);
    for (half, second) in [dst, neg].into_iter().enumerate() {
        let base = half * b * 2 * d;
        for i in 0..b {
            let row = &mut cat[base + i * 2 * d..base + (i + 1) * 2 * d];
            row[..d].copy_from_slice(&src[i * d..(i + 1) * d]);
            row[d..].copy_from_slice(&second[i * d..(i + 1) * d]);
        }
    }
    let mut h = ws.take_full(2 * b * d);
    matmul_into(&cat, w1, 2 * b, 2 * d, d, &mut h, ws);
    kernels::add_bias(&mut h, b1, 2 * b, d);
    for v in h.iter_mut() {
        *v = v.max(0.0);
    }
    let mut pos = ws.take_full(b);
    let mut neg_logit = ws.take_full(b);
    for (li, hrow) in pos.iter_mut().chain(neg_logit.iter_mut()).zip(h.chunks_exact(d)) {
        *li = hrow.iter().zip(w2).map(|(&hj, &wj)| hj * wj).sum::<f64>() + bias2[0];
    }
    Ok((pos, neg_logit, DecCache { cat, h }))
}

/// Backward of ONE role pair's half of the fused decoder: `cat` is the
/// `[b, 2d]` and `h` the `[b, d]` half-slice of the stacked cache. Runs
/// per half rather than over the stacked `2b` rows because the `AᵀB`
/// weight-gradient fold (and the `g_w2` accumulation) would group terms
/// differently over `2b` rows, and invariant 9 pins the f64 path to the
/// seed's bit order.
#[allow(clippy::too_many_arguments)]
fn decode_bwd(
    layout: &[ParamSpec],
    dims: &Dims,
    flat: &[f64],
    cat: &[f64],
    h: &[f64],
    d_logit: &[f64],
    gflat: &mut [f64],
    ws: &Workspace,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let (b, d) = (dims.b, dims.d);
    let w1 = pslice(flat, layout, "dec/W1")?;
    let w2 = pslice(flat, layout, "dec/W2")?;
    let mut d_hpre = ws.take(b * d);
    let mut g_w2 = ws.take(d);
    let mut g_b2 = 0.0;
    for i in 0..b {
        let dl = d_logit[i];
        g_b2 += dl;
        let hrow = &h[i * d..(i + 1) * d];
        let drow = &mut d_hpre[i * d..(i + 1) * d];
        for ((dj, &hj), (&wj, gj)) in
            drow.iter_mut().zip(hrow).zip(w2.iter().zip(g_w2.iter_mut()))
        {
            *gj += hj * dl;
            *dj = if hj > 0.0 { dl * wj } else { 0.0 };
        }
    }
    let mut g_w1 = ws.take(2 * d * d);
    matmul_at_b_into(cat, &d_hpre, b, 2 * d, d, &mut g_w1, ws);
    let mut g_b1 = ws.take(d);
    col_sum_into(&d_hpre, b, d, &mut g_b1);
    let mut d_cat = ws.take(b * 2 * d);
    matmul_a_bt_into(&d_hpre, w1, b, 2 * d, d, &mut d_cat, ws);
    ws.give(d_hpre);
    add_grad(gflat, layout, "dec/W1", &g_w1)?;
    add_grad(gflat, layout, "dec/b1", &g_b1)?;
    add_grad(gflat, layout, "dec/W2", &g_w2)?;
    add_grad(gflat, layout, "dec/b2", &[g_b2])?;
    ws.give(g_w1);
    ws.give(g_b1);
    ws.give(g_w2);
    let mut d_a = ws.take(b * d);
    let mut d_b = ws.take(b * d);
    for i in 0..b {
        let row = &d_cat[i * 2 * d..(i + 1) * 2 * d];
        d_a[i * d..(i + 1) * d].copy_from_slice(&row[..d]);
        d_b[i * d..(i + 1) * d].copy_from_slice(&row[d..]);
    }
    ws.give(d_cat);
    Ok((d_a, d_b))
}

/// One backbone on the native CPU backend.
pub struct NativeModel {
    entry: ModelEntry,
    dims: Dims,
    init: Vec<f32>,
    /// Scratch-buffer arena shared by all kernels (and role threads).
    ws: Workspace,
    /// Persistent f64 mirror of the flat f32 parameter vector.
    flat: Vec<f64>,
    /// Persistent f64 mirrors of the batch tensors.
    bt: Vec<Vec<f64>>,
    /// Persistent flat gradient accumulator.
    gflat: Vec<f64>,
}

impl NativeModel {
    pub(crate) fn new(cfg: &NativeConfig, entry: ModelEntry) -> Self {
        let init = super::init_params(&entry.param_layout, cfg.init_seed);
        Self {
            dims: cfg.dims(),
            entry,
            init,
            ws: Workspace::new(),
            flat: Vec::new(),
            bt: vec![Vec::new(); N_TENSORS],
            gflat: Vec::new(),
        }
    }

    #[allow(clippy::too_many_lines)]
    fn step(&mut self, params32: &[f32], batch: &BatchBuffers, sink: StepSink<'_>) -> Result<()> {
        let dims = self.dims;
        let (b, d, de, td) = (dims.b, dims.d, dims.de, dims.td);
        let mi = dims.mi();
        if params32.len() != self.entry.param_count {
            bail!(
                "param vector has {} f32s, model {:?} expects {}",
                params32.len(),
                self.entry.variant,
                self.entry.param_count
            );
        }
        if batch.bufs.len() != N_TENSORS {
            bail!("batch has {} tensors, expected {N_TENSORS}", batch.bufs.len());
        }

        load_f64(&mut self.flat, params32);
        for (dst, src) in self.bt.iter_mut().zip(&batch.bufs) {
            load_f64(dst, src);
        }
        let layout = &self.entry.param_layout;
        let flat: &[f64] = &self.flat;
        let bt: &[Vec<f64>] = &self.bt;
        let ws = &self.ws;

        // ---- forward: message + memory update (src ∥ dst) ---------------
        let kind = UpdKind::parse(&self.entry.variant.update)?;
        let msg_names: &[&str] = match kind {
            UpdKind::Gru => &MSG_GRU_WEIGHTS,
            UpdKind::Rnn => &MSG_RNN_WEIGHTS,
        };
        let mut w_msg_buf: [&[f64]; 13] = [&[]; 13];
        weight_refs_into(flat, layout, msg_names, &mut w_msg_buf)?;
        let w_msg: &[&[f64]] = &w_msg_buf[..msg_names.len()];
        let ((upd_src, cache_src), (upd_dst, cache_dst)) = tensor::join2(
            || {
                msg_update(
                    kind, &dims, &bt[T_SRC_MEM], &bt[T_DST_MEM], &bt[T_EDGE_FEAT],
                    &bt[T_DT], w_msg, ws,
                )
            },
            || {
                msg_update(
                    kind, &dims, &bt[T_DST_MEM], &bt[T_SRC_MEM], &bt[T_EDGE_FEAT],
                    &bt[T_DT], w_msg, ws,
                )
            },
        );

        // ---- forward: TIGE restart gate --------------------------------
        let build_x = |s_self: &[f64], s_other: &[f64], phi: &[f64], x: &mut [f64]| {
            for i in 0..b {
                let row = &mut x[i * mi..(i + 1) * mi];
                row[..d].copy_from_slice(&s_self[i * d..(i + 1) * d]);
                row[d..2 * d].copy_from_slice(&s_other[i * d..(i + 1) * d]);
                row[2 * d..2 * d + td].copy_from_slice(&phi[i * td..(i + 1) * td]);
                row[2 * d + td..].copy_from_slice(&bt[T_EDGE_FEAT][i * de..(i + 1) * de]);
            }
        };
        let (new_src, new_dst, restart) = if self.entry.variant.restart {
            let w_t = pslice(flat, layout, "msg/w_t")?;
            let b_t = pslice(flat, layout, "msg/b_t")?;
            let res_w = pslice(flat, layout, "res/W")?;
            let res_b = pslice(flat, layout, "res/b")?;
            let mut gate = ws.take(d);
            for (g, &x) in gate.iter_mut().zip(pslice(flat, layout, "res/gate")?) {
                *g = sigmoid(x);
            }
            let mut phi_r = ws.take(b * td);
            time_encode_into(&bt[T_DT], w_t, b_t, &mut phi_r, ws);
            // Both roles share res/W, so the branch runs as one stacked
            // [2b, mi] × [mi, d] GEMM (per-row bit-identical to two b-row
            // calls; see decode_pair's doc for the invariant-9 argument).
            let mut x = ws.take_full(2 * b * mi);
            build_x(&bt[T_SRC_MEM], &bt[T_DST_MEM], &phi_r, &mut x[..b * mi]);
            build_x(&bt[T_DST_MEM], &bt[T_SRC_MEM], &phi_r, &mut x[b * mi..]);
            ws.give(phi_r);
            let mut rst = ws.take_full(2 * b * d);
            matmul_into(&x, res_w, 2 * b, mi, d, &mut rst, ws);
            kernels::add_bias(&mut rst, res_b, 2 * b, d);
            for v in rst.iter_mut() {
                *v = v.tanh();
            }
            let mix = |upd: &[f64], rst: &[f64], out: &mut [f64]| {
                for i in 0..b {
                    for j in 0..d {
                        let g = gate[j];
                        out[i * d + j] = g * upd[i * d + j] + (1.0 - g) * rst[i * d + j];
                    }
                }
            };
            let mut ns = ws.take(b * d);
            mix(&upd_src, &rst[..b * d], &mut ns);
            let mut nd = ws.take(b * d);
            mix(&upd_dst, &rst[b * d..], &mut nd);
            let ctx = RestartCtx { gate, x, rst, upd_src, upd_dst };
            (ns, nd, Some(ctx))
        } else {
            (upd_src, upd_dst, None)
        };

        // ---- forward: embedding module (src ∥ dst ∥ neg) ----------------
        let embed = self.entry.variant.embed.as_str();
        let mut w_att_buf: [&[f64]; 7] = [&[]; 7];
        let w_att: Option<&[&[f64]]> = if embed == "attention" {
            weight_refs_into(flat, layout, &ATTN_WEIGHTS, &mut w_att_buf)?;
            Some(&w_att_buf)
        } else {
            None
        };
        let (emb_src, emb_dst, emb_neg, embed_ctx) = match embed {
            "attention" => {
                let w = w_att.ok_or_else(|| anyhow!("attention weights missing"))?;
                let ((es, ca_s), (ed, ca_d), (en, ca_n)) = tensor::join3(
                    || {
                        attention(
                            &dims, &new_src, &bt[T_SRC_NBR], &bt[T_SRC_NBR + 1],
                            &bt[T_SRC_NBR + 2], &bt[T_SRC_NBR + 3], w, ws,
                        )
                    },
                    || {
                        attention(
                            &dims, &new_dst, &bt[T_DST_NBR], &bt[T_DST_NBR + 1],
                            &bt[T_DST_NBR + 2], &bt[T_DST_NBR + 3], w, ws,
                        )
                    },
                    || {
                        attention(
                            &dims, &bt[T_NEG_MEM], &bt[T_NEG_NBR], &bt[T_NEG_NBR + 1],
                            &bt[T_NEG_NBR + 2], &bt[T_NEG_NBR + 3], w, ws,
                        )
                    },
                );
                (es, ed, en, EmbedCtx::Attn(Box::new((ca_s, ca_d, ca_n))))
            }
            "time_proj" => {
                let w = pslice(flat, layout, "proj/w")?;
                let log1p_into = |dt_last: &[f64], out: &mut [f64]| {
                    for (o, &x) in out.iter_mut().zip(dt_last) {
                        *o = x.max(0.0).ln_1p();
                    }
                };
                let mut u_src = ws.take(b);
                log1p_into(&bt[T_SRC_DT_LAST], &mut u_src);
                let mut u_dst = ws.take(b);
                log1p_into(&bt[T_DST_DT_LAST], &mut u_dst);
                let mut u_neg = ws.take(b);
                log1p_into(&bt[T_NEG_DT_LAST], &mut u_neg);
                let proj = |s: &[f64], u: &[f64], out: &mut [f64]| {
                    for i in 0..b {
                        for (j, &wj) in w.iter().enumerate() {
                            out[i * d + j] = s[i * d + j] * (1.0 + u[i] * wj);
                        }
                    }
                };
                let mut es = ws.take(b * d);
                proj(&new_src, &u_src, &mut es);
                let mut ed = ws.take(b * d);
                proj(&new_dst, &u_dst, &mut ed);
                let mut en = ws.take(b * d);
                proj(&bt[T_NEG_MEM], &u_neg, &mut en);
                (es, ed, en, EmbedCtx::Proj { u_src, u_dst, u_neg })
            }
            "identity" => (
                ws.take_copy(&new_src),
                ws.take_copy(&new_dst),
                ws.take_copy(&bt[T_NEG_MEM]),
                EmbedCtx::Ident,
            ),
            other => bail!("unknown embed module {other:?}"),
        };

        // ---- forward: decode + loss ------------------------------------
        let (pos, neg, dc) =
            decode_pair(layout, &dims, flat, &emb_src, &emb_dst, &emb_neg, ws)?;
        let mask = &bt[T_MASK];
        let denom = mask.iter().sum::<f64>() + 1e-9;
        let loss = pos
            .iter()
            .zip(&neg)
            .zip(mask)
            .map(|((&p, &n), &m)| m * (softplus(-p) + softplus(n)))
            .sum::<f64>()
            / denom;

        let out = match sink {
            StepSink::Eval(out) => {
                out.pos_prob.clear();
                out.pos_prob.extend(pos.iter().map(|&x| sigmoid(x) as f32));
                out.neg_prob.clear();
                out.neg_prob.extend(neg.iter().map(|&x| sigmoid(x) as f32));
                write_f32(&mut out.emb_src, &emb_src);
                write_masked(&mut out.new_src, &new_src, &bt[T_SRC_MEM], mask, b, d);
                write_masked(&mut out.new_dst, &new_dst, &bt[T_DST_MEM], mask, b, d);

                ws.give(pos);
                ws.give(neg);
                dc.recycle(ws);
                release_forward_state(
                    ws, new_src, new_dst, emb_src, emb_dst, emb_neg, embed_ctx, restart,
                    cache_src, cache_dst,
                );
                return Ok(());
            }
            StepSink::Train(out) => out,
        };

        // ---- backward ---------------------------------------------------
        out.loss = loss as f32;
        write_masked(&mut out.new_src, &new_src, &bt[T_SRC_MEM], mask, b, d);
        write_masked(&mut out.new_dst, &new_dst, &bt[T_DST_MEM], mask, b, d);

        let gflat = &mut self.gflat;
        gflat.clear();
        gflat.resize(flat.len(), 0.0);

        let mut d_pos = ws.take(b);
        for ((o, &p), &m) in d_pos.iter_mut().zip(pos.iter()).zip(mask.iter()) {
            *o = -m * sigmoid(-p) / denom;
        }
        let mut d_neg = ws.take(b);
        for ((o, &n), &m) in d_neg.iter_mut().zip(neg.iter()).zip(mask.iter()) {
            *o = m * sigmoid(n) / denom;
        }

        let (mut d_emb_src, d_emb_dst) = decode_bwd(
            layout, &dims, flat, &dc.cat[..b * 2 * d], &dc.h[..b * d], &d_pos, gflat, ws,
        )?;
        let (da, d_emb_neg) = decode_bwd(
            layout, &dims, flat, &dc.cat[b * 2 * d..], &dc.h[b * d..], &d_neg, gflat, ws,
        )?;
        for (acc, &v) in d_emb_src.iter_mut().zip(da.iter()) {
            *acc += v;
        }
        ws.give(da);
        ws.give(d_pos);
        ws.give(d_neg);
        ws.give(pos);
        ws.give(neg);
        dc.recycle(ws);

        let (d_new_src, d_new_dst) = match &embed_ctx {
            EmbedCtx::Attn(caches) => {
                let w = w_att.ok_or_else(|| anyhow!("attention weights missing"))?;
                let (ca_s, ca_d, ca_n) = caches.as_ref();
                let ((g_s, d_ns), (g_d, d_nd), (g_n, d_nn)) = tensor::join3(
                    || attention_bwd(&dims, w, ca_s, &d_emb_src, ws),
                    || attention_bwd(&dims, w, ca_d, &d_emb_dst, ws),
                    || attention_bwd(&dims, w, ca_n, &d_emb_neg, ws),
                );
                // d(neg_mem) is dropped: inputs are leaves.
                ws.give(d_nn);
                for grads in [g_s, g_d, g_n] {
                    for (name, g) in ATTN_WEIGHTS.iter().zip(grads) {
                        add_grad(gflat, layout, name, &g)?;
                        ws.give(g);
                    }
                }
                ws.give(d_emb_src);
                ws.give(d_emb_dst);
                ws.give(d_emb_neg);
                (d_ns, d_nd)
            }
            EmbedCtx::Proj { u_src, u_dst, u_neg } => {
                let w = pslice(flat, layout, "proj/w")?;
                let mut g_w = ws.take(d);
                let bwd = |d_emb: &[f64], s: &[f64], u: &[f64], g_w: &mut [f64]| -> Vec<f64> {
                    let mut d_s = ws.take(b * d);
                    for i in 0..b {
                        for (j, (&wj, gj)) in w.iter().zip(g_w.iter_mut()).enumerate() {
                            let de_ij = d_emb[i * d + j];
                            d_s[i * d + j] = de_ij * (1.0 + u[i] * wj);
                            *gj += de_ij * s[i * d + j] * u[i];
                        }
                    }
                    d_s
                };
                let d_ns = bwd(&d_emb_src, &new_src, u_src, &mut g_w);
                let d_nd = bwd(&d_emb_dst, &new_dst, u_dst, &mut g_w);
                let d_nn = bwd(&d_emb_neg, &bt[T_NEG_MEM], u_neg, &mut g_w);
                ws.give(d_nn);
                add_grad(gflat, layout, "proj/w", &g_w)?;
                ws.give(g_w);
                ws.give(d_emb_src);
                ws.give(d_emb_dst);
                ws.give(d_emb_neg);
                (d_ns, d_nd)
            }
            EmbedCtx::Ident => {
                ws.give(d_emb_neg);
                (d_emb_src, d_emb_dst)
            }
        };

        // ---- backward: restart gate ------------------------------------
        let (d_upd_src, d_upd_dst) = if let Some(ctx) = &restart {
            let res_w = pslice(flat, layout, "res/W")?;
            let w_t = pslice(flat, layout, "msg/w_t")?;
            let b_t = pslice(flat, layout, "msg/b_t")?;
            // Gate gradient (elementwise over d, summed over the batch).
            let mut d_gate = ws.take(d);
            for i in 0..b {
                for (j, g) in d_gate.iter_mut().enumerate() {
                    *g += d_new_src[i * d + j]
                        * (ctx.upd_src[i * d + j] - ctx.rst[i * d + j])
                        + d_new_dst[i * d + j]
                            * (ctx.upd_dst[i * d + j] - ctx.rst[b * d + i * d + j]);
                }
            }
            let mut g_gate = ws.take(d);
            for ((o, &dg), &g) in g_gate.iter_mut().zip(d_gate.iter()).zip(ctx.gate.iter()) {
                *o = dg * g * (1.0 - g);
            }
            add_grad(gflat, layout, "res/gate", &g_gate)?;
            ws.give(g_gate);
            ws.give(d_gate);

            let scale_gate = |d_new: &[f64], out: &mut [f64]| {
                for i in 0..b {
                    for (j, &g) in ctx.gate.iter().enumerate() {
                        out[i * d + j] = d_new[i * d + j] * g;
                    }
                }
            };
            let mut d_us = ws.take(b * d);
            scale_gate(&d_new_src, &mut d_us);
            let mut d_ud = ws.take(b * d);
            scale_gate(&d_new_dst, &mut d_ud);

            let mut d_phi_r = ws.take(b * td);
            let mut g_res_w = ws.take(mi * d);
            let mut g_res_b = ws.take(d);
            let mut d_a = ws.take(b * d);
            let mut g_tmp = ws.take(mi * d);
            let mut b_tmp = ws.take(d);
            let mut d_x = ws.take(b * mi);
            for (x, rst, d_new) in [
                (&ctx.x[..b * mi], &ctx.rst[..b * d], &d_new_src[..]),
                (&ctx.x[b * mi..], &ctx.rst[b * d..], &d_new_dst[..]),
            ] {
                for i in 0..b {
                    for (j, &g) in ctx.gate.iter().enumerate() {
                        let r = rst[i * d + j];
                        d_a[i * d + j] = d_new[i * d + j] * (1.0 - g) * (1.0 - r * r);
                    }
                }
                matmul_at_b_into(x, &d_a, b, mi, d, &mut g_tmp, ws);
                for (acc, &v) in g_res_w.iter_mut().zip(g_tmp.iter()) {
                    *acc += v;
                }
                col_sum_into(&d_a, b, d, &mut b_tmp);
                for (acc, &v) in g_res_b.iter_mut().zip(b_tmp.iter()) {
                    *acc += v;
                }
                matmul_a_bt_into(&d_a, res_w, b, mi, d, &mut d_x, ws);
                for i in 0..b {
                    for (acc, &v) in d_phi_r[i * td..(i + 1) * td]
                        .iter_mut()
                        .zip(&d_x[i * mi + 2 * d..i * mi + 2 * d + td])
                    {
                        *acc += v;
                    }
                }
            }
            ws.give(d_a);
            ws.give(g_tmp);
            ws.give(b_tmp);
            ws.give(d_x);
            add_grad(gflat, layout, "res/W", &g_res_w)?;
            add_grad(gflat, layout, "res/b", &g_res_b)?;
            ws.give(g_res_w);
            ws.give(g_res_b);
            let mut g_wt = ws.take(td);
            let mut g_bt = ws.take(td);
            time_encode_bwd(&bt[T_DT], w_t, b_t, &d_phi_r, &mut g_wt, &mut g_bt);
            add_grad(gflat, layout, "msg/w_t", &g_wt)?;
            add_grad(gflat, layout, "msg/b_t", &g_bt)?;
            ws.give(g_wt);
            ws.give(g_bt);
            ws.give(d_phi_r);
            ws.give(d_new_src);
            ws.give(d_new_dst);
            (d_us, d_ud)
        } else {
            (d_new_src, d_new_dst)
        };

        // ---- backward: fused message + update (src ∥ dst) ---------------
        let (g_src, g_dst) = tensor::join2(
            || msg_update_bwd(kind, &dims, w_msg, &cache_src, &d_upd_src, ws),
            || msg_update_bwd(kind, &dims, w_msg, &cache_dst, &d_upd_dst, ws),
        );
        for grads in [g_src, g_dst] {
            for (name, g) in msg_names.iter().zip(grads) {
                add_grad(gflat, layout, name, &g)?;
                ws.give(g);
            }
        }
        ws.give(d_upd_src);
        ws.give(d_upd_dst);
        release_forward_state(
            ws, new_src, new_dst, emb_src, emb_dst, emb_neg, embed_ctx, restart, cache_src,
            cache_dst,
        );

        write_f32(&mut out.grads, gflat);
        Ok(())
    }
}

impl ModelBackend for NativeModel {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn init_params(&self) -> &[f32] {
        &self.init
    }

    fn train_step_into(
        &mut self,
        params: &[f32],
        batch: &BatchBuffers,
        out: &mut TrainOut,
    ) -> Result<()> {
        self.step(params, batch, StepSink::Train(out))
    }

    fn eval_step_into(
        &mut self,
        params: &[f32],
        batch: &BatchBuffers,
        out: &mut EvalOut,
    ) -> Result<()> {
        self.step(params, batch, StepSink::Eval(out))
    }
}
