//! The full generalized TIG encoder-decoder step (Sec. II-C) on the native
//! kernels: Memory → Message → Update → (Restart) → Embed → Decode, its
//! BCE link-prediction loss, and the composed analytic backward pass.
//!
//! Semantics are identical to `python/compile/model.py::_forward` /
//! `make_train_step` / `make_eval_step` (minus the numerically irrelevant
//! `_touch` term that only pins the HLO signature): padded rows (mask 0)
//! contribute nothing to the loss and keep their input memory; negatives
//! never update memory. Verified end-to-end against `jax.value_and_grad`
//! fixtures in `rust/tests/golden.rs`.

use anyhow::{anyhow, bail, Result};

use crate::backend::{
    BatchBuffers, EvalOut, ModelBackend, ModelEntry, ParamSpec, TrainOut, N_TENSORS,
    T_DST_DT_LAST, T_DST_MEM, T_DST_NBR, T_DT, T_EDGE_FEAT, T_MASK, T_NEG_DT_LAST,
    T_NEG_MEM, T_NEG_NBR, T_SRC_DT_LAST, T_SRC_MEM, T_SRC_NBR,
};

use super::kernels::{
    self, attention, attention_bwd, col_sum, matmul, matmul_a_bt, matmul_at_b,
    msg_update, msg_update_bwd, sigmoid, softplus, time_encode, time_encode_bwd,
    AttnCache, Dims, UpdKind,
};
use super::NativeConfig;

/// Manifest parameter names feeding the fused update kernel, in its weight
/// order (mirrors `python/compile/model.py::_update_weights`).
const MSG_GRU_WEIGHTS: [&str; 13] = [
    "msg/w_t", "msg/b_t", "msg/Wm", "msg/bm",
    "upd/Wz", "upd/Uz", "upd/bz",
    "upd/Wr", "upd/Ur", "upd/br",
    "upd/Wh", "upd/Uh", "upd/bh",
];
const MSG_RNN_WEIGHTS: [&str; 7] =
    ["msg/w_t", "msg/b_t", "msg/Wm", "msg/bm", "upd/W", "upd/U", "upd/b"];
/// Attention kernel weight order (`_attn_weights`).
const ATTN_WEIGHTS: [&str; 7] =
    ["att/w_t", "att/b_t", "att/Wq", "att/Wk", "att/Wv", "att/Wo", "att/bo"];

fn find<'a>(layout: &'a [ParamSpec], name: &str) -> Result<&'a ParamSpec> {
    layout
        .iter()
        .find(|p| p.name == name)
        .ok_or_else(|| anyhow!("param {name:?} not in layout"))
}

fn pslice<'a>(flat: &'a [f64], layout: &[ParamSpec], name: &str) -> Result<&'a [f64]> {
    let s = find(layout, name)?;
    Ok(&flat[s.offset..s.offset + s.elements()])
}

fn weight_refs<'a>(
    flat: &'a [f64],
    layout: &[ParamSpec],
    names: &[&str],
) -> Result<Vec<&'a [f64]>> {
    names.iter().map(|n| pslice(flat, layout, n)).collect()
}

fn add_grad(gflat: &mut [f64], layout: &[ParamSpec], name: &str, vals: &[f64]) -> Result<()> {
    let s = find(layout, name)?;
    if vals.len() != s.elements() {
        bail!("gradient size mismatch for {name:?}: {} != {}", vals.len(), s.elements());
    }
    for (g, &v) in gflat[s.offset..s.offset + s.elements()].iter_mut().zip(vals) {
        *g += v;
    }
    Ok(())
}

/// Cached restart-branch forward state (TIGE).
struct RestartCtx {
    gate: Vec<f64>,
    x_src: Vec<f64>,
    rst_src: Vec<f64>,
    x_dst: Vec<f64>,
    rst_dst: Vec<f64>,
    upd_src: Vec<f64>,
    upd_dst: Vec<f64>,
}

/// Cached embedding-module forward state.
enum EmbedCtx {
    Attn(Box<(AttnCache, AttnCache, AttnCache)>),
    Proj { u_src: Vec<f64>, u_dst: Vec<f64>, u_neg: Vec<f64> },
    Ident,
}

struct DecCache {
    cat: Vec<f64>,
    h: Vec<f64>,
}

struct StepOut {
    loss: f64,
    grads: Option<Vec<f32>>,
    new_src: Vec<f32>,
    new_dst: Vec<f32>,
    pos_prob: Vec<f32>,
    neg_prob: Vec<f32>,
    emb_src: Vec<f32>,
}

/// One backbone on the native CPU backend.
pub struct NativeModel {
    entry: ModelEntry,
    dims: Dims,
    init: Vec<f32>,
}

impl NativeModel {
    pub(crate) fn new(cfg: &NativeConfig, entry: ModelEntry) -> Self {
        let init = super::init_params(&entry.param_layout, cfg.init_seed);
        Self { dims: cfg.dims(), entry, init }
    }

    fn decode(
        &self,
        flat: &[f64],
        a: &[f64],
        b2nd: &[f64],
    ) -> Result<(Vec<f64>, DecCache)> {
        let layout = &self.entry.param_layout;
        let (b, d) = (self.dims.b, self.dims.d);
        let w1 = pslice(flat, layout, "dec/W1")?;
        let b1 = pslice(flat, layout, "dec/b1")?;
        let w2 = pslice(flat, layout, "dec/W2")?;
        let bias2 = pslice(flat, layout, "dec/b2")?;
        let mut cat = vec![0.0; b * 2 * d];
        for i in 0..b {
            let row = &mut cat[i * 2 * d..(i + 1) * 2 * d];
            row[..d].copy_from_slice(&a[i * d..(i + 1) * d]);
            row[d..].copy_from_slice(&b2nd[i * d..(i + 1) * d]);
        }
        let mut h = matmul(&cat, w1, b, 2 * d, d);
        kernels::add_bias(&mut h, b1, b, d);
        for v in h.iter_mut() {
            *v = v.max(0.0);
        }
        let logit: Vec<f64> = (0..b)
            .map(|i| {
                h[i * d..(i + 1) * d]
                    .iter()
                    .zip(w2)
                    .map(|(&hj, &wj)| hj * wj)
                    .sum::<f64>()
                    + bias2[0]
            })
            .collect();
        Ok((logit, DecCache { cat, h }))
    }

    fn decode_bwd(
        &self,
        flat: &[f64],
        cache: &DecCache,
        d_logit: &[f64],
        gflat: &mut [f64],
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let layout = &self.entry.param_layout;
        let (b, d) = (self.dims.b, self.dims.d);
        let w1 = pslice(flat, layout, "dec/W1")?;
        let w2 = pslice(flat, layout, "dec/W2")?;
        let mut d_hpre = vec![0.0; b * d];
        let mut g_w2 = vec![0.0; d];
        let mut g_b2 = 0.0;
        for i in 0..b {
            let dl = d_logit[i];
            g_b2 += dl;
            let hrow = &cache.h[i * d..(i + 1) * d];
            let drow = &mut d_hpre[i * d..(i + 1) * d];
            for ((dj, &hj), (&wj, gj)) in
                drow.iter_mut().zip(hrow).zip(w2.iter().zip(g_w2.iter_mut()))
            {
                *gj += hj * dl;
                *dj = if hj > 0.0 { dl * wj } else { 0.0 };
            }
        }
        let g_w1 = matmul_at_b(&cache.cat, &d_hpre, b, 2 * d, d);
        let g_b1 = col_sum(&d_hpre, b, d);
        let d_cat = matmul_a_bt(&d_hpre, w1, b, 2 * d, d);
        add_grad(gflat, layout, "dec/W1", &g_w1)?;
        add_grad(gflat, layout, "dec/b1", &g_b1)?;
        add_grad(gflat, layout, "dec/W2", &g_w2)?;
        add_grad(gflat, layout, "dec/b2", &[g_b2])?;
        let mut d_a = vec![0.0; b * d];
        let mut d_b = vec![0.0; b * d];
        for i in 0..b {
            let row = &d_cat[i * 2 * d..(i + 1) * 2 * d];
            d_a[i * d..(i + 1) * d].copy_from_slice(&row[..d]);
            d_b[i * d..(i + 1) * d].copy_from_slice(&row[d..]);
        }
        Ok((d_a, d_b))
    }

    #[allow(clippy::too_many_lines)]
    fn step(&self, params32: &[f32], batch: &BatchBuffers, want_grads: bool) -> Result<StepOut> {
        let dims = self.dims;
        let (b, d, de, td) = (dims.b, dims.d, dims.de, dims.td);
        let mi = dims.mi();
        let layout = &self.entry.param_layout;
        if params32.len() != self.entry.param_count {
            bail!(
                "param vector has {} f32s, model {:?} expects {}",
                params32.len(),
                self.entry.variant,
                self.entry.param_count
            );
        }
        if batch.bufs.len() != N_TENSORS {
            bail!("batch has {} tensors, expected {N_TENSORS}", batch.bufs.len());
        }

        let flat: Vec<f64> = params32.iter().map(|&x| x as f64).collect();
        let bt: Vec<Vec<f64>> = batch
            .bufs
            .iter()
            .map(|v| v.iter().map(|&x| x as f64).collect())
            .collect();

        // ---- forward: message + memory update --------------------------
        let kind = UpdKind::parse(&self.entry.variant.update)?;
        let msg_names: &[&str] = match kind {
            UpdKind::Gru => &MSG_GRU_WEIGHTS,
            UpdKind::Rnn => &MSG_RNN_WEIGHTS,
        };
        let w_msg = weight_refs(&flat, layout, msg_names)?;
        let (upd_src, cache_src) = msg_update(
            kind, &dims, &bt[T_SRC_MEM], &bt[T_DST_MEM], &bt[T_EDGE_FEAT], &bt[T_DT], &w_msg,
        );
        let (upd_dst, cache_dst) = msg_update(
            kind, &dims, &bt[T_DST_MEM], &bt[T_SRC_MEM], &bt[T_EDGE_FEAT], &bt[T_DT], &w_msg,
        );

        // ---- forward: TIGE restart gate --------------------------------
        let build_x = |s_self: &[f64], s_other: &[f64], phi: &[f64]| -> Vec<f64> {
            let mut x = vec![0.0; b * mi];
            for i in 0..b {
                let row = &mut x[i * mi..(i + 1) * mi];
                row[..d].copy_from_slice(&s_self[i * d..(i + 1) * d]);
                row[d..2 * d].copy_from_slice(&s_other[i * d..(i + 1) * d]);
                row[2 * d..2 * d + td].copy_from_slice(&phi[i * td..(i + 1) * td]);
                row[2 * d + td..].copy_from_slice(&bt[T_EDGE_FEAT][i * de..(i + 1) * de]);
            }
            x
        };
        let (new_src, new_dst, restart) = if self.entry.variant.restart {
            let w_t = pslice(&flat, layout, "msg/w_t")?;
            let b_t = pslice(&flat, layout, "msg/b_t")?;
            let res_w = pslice(&flat, layout, "res/W")?;
            let res_b = pslice(&flat, layout, "res/b")?;
            let gate: Vec<f64> = pslice(&flat, layout, "res/gate")?
                .iter()
                .map(|&x| sigmoid(x))
                .collect();
            let phi_r = time_encode(&bt[T_DT], w_t, b_t);
            let branch = |x: &[f64]| -> Vec<f64> {
                let mut a = matmul(x, res_w, b, mi, d);
                kernels::add_bias(&mut a, res_b, b, d);
                a.iter().map(|&v| v.tanh()).collect()
            };
            let x_src = build_x(&bt[T_SRC_MEM], &bt[T_DST_MEM], &phi_r);
            let rst_src = branch(&x_src);
            let x_dst = build_x(&bt[T_DST_MEM], &bt[T_SRC_MEM], &phi_r);
            let rst_dst = branch(&x_dst);
            let mix = |upd: &[f64], rst: &[f64]| -> Vec<f64> {
                let mut out = vec![0.0; b * d];
                for i in 0..b {
                    for j in 0..d {
                        let g = gate[j];
                        out[i * d + j] = g * upd[i * d + j] + (1.0 - g) * rst[i * d + j];
                    }
                }
                out
            };
            let ns = mix(&upd_src, &rst_src);
            let nd = mix(&upd_dst, &rst_dst);
            let ctx = RestartCtx {
                gate,
                x_src,
                rst_src,
                x_dst,
                rst_dst,
                upd_src,
                upd_dst,
            };
            (ns, nd, Some(ctx))
        } else {
            (upd_src, upd_dst, None)
        };

        // ---- forward: embedding module ---------------------------------
        let embed = self.entry.variant.embed.as_str();
        let w_att = if embed == "attention" {
            Some(weight_refs(&flat, layout, &ATTN_WEIGHTS)?)
        } else {
            None
        };
        let log1p_rows = |dt_last: &[f64]| -> Vec<f64> {
            dt_last.iter().map(|&x| x.max(0.0).ln_1p()).collect()
        };
        let (emb_src, emb_dst, emb_neg, embed_ctx) = match embed {
            "attention" => {
                let w = w_att.as_ref().unwrap();
                let (es, ca_s) = attention(
                    &dims, &new_src, &bt[T_SRC_NBR], &bt[T_SRC_NBR + 1],
                    &bt[T_SRC_NBR + 2], &bt[T_SRC_NBR + 3], w,
                );
                let (ed, ca_d) = attention(
                    &dims, &new_dst, &bt[T_DST_NBR], &bt[T_DST_NBR + 1],
                    &bt[T_DST_NBR + 2], &bt[T_DST_NBR + 3], w,
                );
                let (en, ca_n) = attention(
                    &dims, &bt[T_NEG_MEM], &bt[T_NEG_NBR], &bt[T_NEG_NBR + 1],
                    &bt[T_NEG_NBR + 2], &bt[T_NEG_NBR + 3], w,
                );
                (es, ed, en, EmbedCtx::Attn(Box::new((ca_s, ca_d, ca_n))))
            }
            "time_proj" => {
                let w = pslice(&flat, layout, "proj/w")?;
                let u_src = log1p_rows(&bt[T_SRC_DT_LAST]);
                let u_dst = log1p_rows(&bt[T_DST_DT_LAST]);
                let u_neg = log1p_rows(&bt[T_NEG_DT_LAST]);
                let proj = |s: &[f64], u: &[f64]| -> Vec<f64> {
                    let mut out = vec![0.0; b * d];
                    for i in 0..b {
                        for (j, &wj) in w.iter().enumerate() {
                            out[i * d + j] = s[i * d + j] * (1.0 + u[i] * wj);
                        }
                    }
                    out
                };
                let es = proj(&new_src, &u_src);
                let ed = proj(&new_dst, &u_dst);
                let en = proj(&bt[T_NEG_MEM], &u_neg);
                (es, ed, en, EmbedCtx::Proj { u_src, u_dst, u_neg })
            }
            "identity" => (
                new_src.clone(),
                new_dst.clone(),
                bt[T_NEG_MEM].clone(),
                EmbedCtx::Ident,
            ),
            other => bail!("unknown embed module {other:?}"),
        };

        // ---- forward: decode + loss ------------------------------------
        let (pos, dc_pos) = self.decode(&flat, &emb_src, &emb_dst)?;
        let (neg, dc_neg) = self.decode(&flat, &emb_src, &emb_neg)?;
        let mask = &bt[T_MASK];
        let denom = mask.iter().sum::<f64>() + 1e-9;
        let loss = pos
            .iter()
            .zip(&neg)
            .zip(mask)
            .map(|((&p, &n), &m)| m * (softplus(-p) + softplus(n)))
            .sum::<f64>()
            / denom;

        let masked = |new: &[f64], old: &[f64]| -> Vec<f32> {
            let mut out = vec![0.0f32; b * d];
            for i in 0..b {
                let m = mask[i];
                for j in 0..d {
                    out[i * d + j] =
                        (m * new[i * d + j] + (1.0 - m) * old[i * d + j]) as f32;
                }
            }
            out
        };
        let out_src = masked(&new_src, &bt[T_SRC_MEM]);
        let out_dst = masked(&new_dst, &bt[T_DST_MEM]);
        let pos_prob: Vec<f32> = pos.iter().map(|&x| sigmoid(x) as f32).collect();
        let neg_prob: Vec<f32> = neg.iter().map(|&x| sigmoid(x) as f32).collect();
        let emb_src32: Vec<f32> = emb_src.iter().map(|&x| x as f32).collect();

        if !want_grads {
            return Ok(StepOut {
                loss,
                grads: None,
                new_src: out_src,
                new_dst: out_dst,
                pos_prob,
                neg_prob,
                emb_src: emb_src32,
            });
        }

        // ---- backward ---------------------------------------------------
        let mut gflat = vec![0.0f64; flat.len()];
        let d_pos: Vec<f64> =
            pos.iter().zip(mask).map(|(&p, &m)| -m * sigmoid(-p) / denom).collect();
        let d_neg: Vec<f64> =
            neg.iter().zip(mask).map(|(&n, &m)| m * sigmoid(n) / denom).collect();

        let (mut d_emb_src, d_emb_dst) =
            self.decode_bwd(&flat, &dc_pos, &d_pos, &mut gflat)?;
        let (da, d_emb_neg) = self.decode_bwd(&flat, &dc_neg, &d_neg, &mut gflat)?;
        for (acc, v) in d_emb_src.iter_mut().zip(da) {
            *acc += v;
        }

        let (d_new_src, d_new_dst) = match &embed_ctx {
            EmbedCtx::Attn(caches) => {
                let w = w_att.as_ref().unwrap();
                let (ca_s, ca_d, ca_n) = caches.as_ref();
                let (g_s, d_ns) = attention_bwd(&dims, w, ca_s, &d_emb_src);
                let (g_d, d_nd) = attention_bwd(&dims, w, ca_d, &d_emb_dst);
                // d(neg_mem) is dropped: inputs are leaves.
                let (g_n, _) = attention_bwd(&dims, w, ca_n, &d_emb_neg);
                for grads in [g_s, g_d, g_n] {
                    for (name, g) in ATTN_WEIGHTS.iter().zip(grads) {
                        add_grad(&mut gflat, layout, name, &g)?;
                    }
                }
                (d_ns, d_nd)
            }
            EmbedCtx::Proj { u_src, u_dst, u_neg } => {
                let w = pslice(&flat, layout, "proj/w")?;
                let mut g_w = vec![0.0; d];
                let mut bwd = |d_emb: &[f64], s: &[f64], u: &[f64]| -> Vec<f64> {
                    let mut d_s = vec![0.0; b * d];
                    for i in 0..b {
                        for (j, (&wj, gj)) in w.iter().zip(g_w.iter_mut()).enumerate() {
                            let de_ij = d_emb[i * d + j];
                            d_s[i * d + j] = de_ij * (1.0 + u[i] * wj);
                            *gj += de_ij * s[i * d + j] * u[i];
                        }
                    }
                    d_s
                };
                let d_ns = bwd(&d_emb_src, &new_src, u_src);
                let d_nd = bwd(&d_emb_dst, &new_dst, u_dst);
                let _ = bwd(&d_emb_neg, &bt[T_NEG_MEM], u_neg);
                add_grad(&mut gflat, layout, "proj/w", &g_w)?;
                (d_ns, d_nd)
            }
            EmbedCtx::Ident => (d_emb_src, d_emb_dst),
        };

        // ---- backward: restart gate ------------------------------------
        let (d_upd_src, d_upd_dst) = if let Some(ctx) = &restart {
            let res_w = pslice(&flat, layout, "res/W")?;
            let w_t = pslice(&flat, layout, "msg/w_t")?;
            let b_t = pslice(&flat, layout, "msg/b_t")?;
            // Gate gradient (elementwise over d, summed over the batch).
            let mut d_gate = vec![0.0; d];
            for i in 0..b {
                for (j, g) in d_gate.iter_mut().enumerate() {
                    *g += d_new_src[i * d + j]
                        * (ctx.upd_src[i * d + j] - ctx.rst_src[i * d + j])
                        + d_new_dst[i * d + j]
                            * (ctx.upd_dst[i * d + j] - ctx.rst_dst[i * d + j]);
                }
            }
            let g_gate: Vec<f64> = d_gate
                .iter()
                .zip(&ctx.gate)
                .map(|(&dg, &g)| dg * g * (1.0 - g))
                .collect();
            add_grad(&mut gflat, layout, "res/gate", &g_gate)?;

            let scale_gate = |d_new: &[f64]| -> Vec<f64> {
                let mut out = vec![0.0; b * d];
                for i in 0..b {
                    for (j, &g) in ctx.gate.iter().enumerate() {
                        out[i * d + j] = d_new[i * d + j] * g;
                    }
                }
                out
            };
            let d_us = scale_gate(&d_new_src);
            let d_ud = scale_gate(&d_new_dst);

            let mut d_phi_r = vec![0.0; b * td];
            let mut g_res_w = vec![0.0; res_w.len()];
            let mut g_res_b = vec![0.0; d];
            for (x, rst, d_new) in [
                (&ctx.x_src, &ctx.rst_src, &d_new_src),
                (&ctx.x_dst, &ctx.rst_dst, &d_new_dst),
            ] {
                let mut d_a = vec![0.0; b * d];
                for i in 0..b {
                    for (j, &g) in ctx.gate.iter().enumerate() {
                        let r = rst[i * d + j];
                        d_a[i * d + j] = d_new[i * d + j] * (1.0 - g) * (1.0 - r * r);
                    }
                }
                for (acc, v) in g_res_w.iter_mut().zip(matmul_at_b(x, &d_a, b, mi, d)) {
                    *acc += v;
                }
                for (acc, v) in g_res_b.iter_mut().zip(col_sum(&d_a, b, d)) {
                    *acc += v;
                }
                let d_x = matmul_a_bt(&d_a, res_w, b, mi, d);
                for i in 0..b {
                    for (acc, &v) in d_phi_r[i * td..(i + 1) * td]
                        .iter_mut()
                        .zip(&d_x[i * mi + 2 * d..i * mi + 2 * d + td])
                    {
                        *acc += v;
                    }
                }
            }
            add_grad(&mut gflat, layout, "res/W", &g_res_w)?;
            add_grad(&mut gflat, layout, "res/b", &g_res_b)?;
            let mut g_wt = vec![0.0; td];
            let mut g_bt = vec![0.0; td];
            time_encode_bwd(&bt[T_DT], w_t, b_t, &d_phi_r, &mut g_wt, &mut g_bt);
            add_grad(&mut gflat, layout, "msg/w_t", &g_wt)?;
            add_grad(&mut gflat, layout, "msg/b_t", &g_bt)?;
            (d_us, d_ud)
        } else {
            (d_new_src, d_new_dst)
        };

        // ---- backward: fused message + update --------------------------
        for (cache, d_upd) in [(&cache_src, &d_upd_src), (&cache_dst, &d_upd_dst)] {
            let grads = msg_update_bwd(kind, &dims, &w_msg, cache, d_upd);
            for (name, g) in msg_names.iter().zip(grads) {
                add_grad(&mut gflat, layout, name, &g)?;
            }
        }

        let grads32: Vec<f32> = gflat.iter().map(|&x| x as f32).collect();
        Ok(StepOut {
            loss,
            grads: Some(grads32),
            new_src: out_src,
            new_dst: out_dst,
            pos_prob,
            neg_prob,
            emb_src: emb_src32,
        })
    }
}

impl ModelBackend for NativeModel {
    fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn init_params(&self) -> &[f32] {
        &self.init
    }

    fn train_step(&mut self, params: &[f32], batch: &BatchBuffers) -> Result<TrainOut> {
        let out = self.step(params, batch, true)?;
        Ok(TrainOut {
            loss: out.loss as f32,
            grads: out.grads.expect("train step computes gradients"),
            new_src: out.new_src,
            new_dst: out.new_dst,
        })
    }

    fn eval_step(&mut self, params: &[f32], batch: &BatchBuffers) -> Result<EvalOut> {
        let out = self.step(params, batch, false)?;
        Ok(EvalOut {
            pos_prob: out.pos_prob,
            neg_prob: out.neg_prob,
            new_src: out.new_src,
            new_dst: out.new_dst,
            emb_src: out.emb_src,
        })
    }
}
