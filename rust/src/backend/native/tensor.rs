//! The workspace-arena tensor layer under the native backend.
//!
//! Three concerns live here, all on the per-step critical path of every PAC
//! worker:
//!
//! * [`Workspace`] — a shape-tagged arena of reusable `f64` scratch buffers
//!   (plus an `f32` twin pool backing the `simd` feature's lane buffers).
//!   Every forward/backward kernel takes its temporaries from the arena and
//!   gives them back, so a train step performs **zero** heap allocations
//!   once the pool is warm. The pool is shared behind an `Arc<Mutex<..>>`
//!   so the parallel role closures can take/give concurrently; a buffer's
//!   identity never affects the math (buffers come back zero-filled), so
//!   sharing costs nothing in determinism.
//! * Blocked dense kernels (`matmul_into`, `matmul_at_b_into`,
//!   `matmul_a_bt_into`) that write into caller-provided slices, with a
//!   deterministic thread-parallel path behind the `parallel` cargo
//!   feature: row ranges (and, for the `AᵀB` reduction, **fixed** row
//!   blocks folded in index order) are split at points that depend only on
//!   the shapes — never on the thread count — so the parallel results are
//!   bit-identical to the serial ones.
//! * f32 lane kernels behind the `simd` cargo feature: operands narrow to
//!   pooled f32 buffers once per call and products accumulate in fixed
//!   8-wide lanes (plain indexed loops over `[f32; 8]`-shaped chunks that
//!   LLVM autovectorizes on stable — no `std::simd`), with lane blocks
//!   folded into f64 every [`F32_KBLOCK`] k-steps so accumulation error
//!   stays bounded independently of the contraction depth. The f64 path is
//!   the *same code* whether or not the feature is on (invariant 9,
//!   docs/INVARIANTS.md): `simd` only flips the runtime dispatch default,
//!   and [`set_f32_compute`] can flip it back — which is how the bench
//!   binary times both compute paths from one build.
//!
//! rayon is unavailable offline, so the `parallel` feature uses
//! `std::thread::scope` directly; the thread budget honors
//! `RAYON_NUM_THREADS` (then `SPEED_NUM_THREADS`) for familiarity and can
//! be pinned programmatically with [`set_threads`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Free buffers, keyed by exact length (the "shape tag").
type Pool = BTreeMap<usize, Vec<Vec<f64>>>;

/// Free f32 lane buffers, keyed by exact length — the `simd` twin of
/// [`Pool`].
type Pool32 = BTreeMap<usize, Vec<Vec<f32>>>;

/// A shared arena of reusable scratch buffers.
///
/// Cloning a `Workspace` clones the *handle*: all clones draw from the same
/// pool, which is what lets parallel kernel tasks recycle buffers without
/// per-role bookkeeping.
#[derive(Debug, Clone, Default)]
pub struct Workspace {
    pool: Arc<Mutex<Pool>>,
    pool32: Arc<Mutex<Pool32>>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zero-filled buffer of exactly `len` elements (recycled if one of
    /// this length is pooled, freshly allocated otherwise).
    pub fn take(&self, len: usize) -> Vec<f64> {
        let recycled = self.pool.lock().expect("workspace pool mutex poisoned").get_mut(&len).and_then(Vec::pop);
        match recycled {
            Some(mut v) => {
                v.fill(0.0);
                v
            }
            None => vec![0.0; len],
        }
    }

    /// A buffer of exactly `len` elements with **unspecified contents**
    /// (stale values from its previous use). Only for consumers that
    /// provably overwrite every element before reading — accumulators
    /// must use [`Workspace::take`], which zero-fills.
    pub fn take_full(&self, len: usize) -> Vec<f64> {
        let recycled = self.pool.lock().expect("workspace pool mutex poisoned").get_mut(&len).and_then(Vec::pop);
        recycled.unwrap_or_else(|| vec![0.0; len])
    }

    /// A buffer holding a copy of `src`.
    pub fn take_copy(&self, src: &[f64]) -> Vec<f64> {
        let recycled = self.pool.lock().expect("workspace pool mutex poisoned").get_mut(&src.len()).and_then(Vec::pop);
        match recycled {
            Some(mut v) => {
                v.copy_from_slice(src);
                v
            }
            None => src.to_vec(),
        }
    }

    /// Return a buffer to the pool (empty buffers are dropped).
    pub fn give(&self, v: Vec<f64>) {
        if !v.is_empty() {
            self.pool.lock().expect("workspace pool mutex poisoned").entry(v.len()).or_default().push(v);
        }
    }

    /// A zero-filled f32 lane buffer — the `simd` compute path's scratch,
    /// recycled like [`Workspace::take`].
    pub fn take32(&self, len: usize) -> Vec<f32> {
        let recycled = self.pool32.lock().expect("workspace pool mutex poisoned").get_mut(&len).and_then(Vec::pop);
        match recycled {
            Some(mut v) => {
                v.fill(0.0);
                v
            }
            None => vec![0.0; len],
        }
    }

    /// An f32 lane buffer with **unspecified contents** — the f32 twin of
    /// [`Workspace::take_full`]; consumers must overwrite every element
    /// before reading.
    pub fn take32_full(&self, len: usize) -> Vec<f32> {
        let recycled = self.pool32.lock().expect("workspace pool mutex poisoned").get_mut(&len).and_then(Vec::pop);
        recycled.unwrap_or_else(|| vec![0.0; len])
    }

    /// Return an f32 lane buffer to the pool (empty buffers are dropped).
    pub fn give32(&self, v: Vec<f32>) {
        if !v.is_empty() {
            self.pool32.lock().expect("workspace pool mutex poisoned").entry(v.len()).or_default().push(v);
        }
    }

    /// Pooled buffer count across both element types (diagnostics/tests).
    pub fn pooled(&self) -> usize {
        let p64: usize = self.pool.lock().expect("workspace pool mutex poisoned").values().map(Vec::len).sum();
        let p32: usize = self.pool32.lock().expect("workspace pool mutex poisoned").values().map(Vec::len).sum();
        p64 + p32
    }
}

// -- thread budget ---------------------------------------------------------

static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Pin the kernel thread budget (`0` = auto-detect). Only effective with
/// the `parallel` cargo feature; the default build always runs serial.
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// The current override as set by [`set_threads`] (`0` = auto). Lets a
/// caller that pins the budget temporarily restore the previous state.
pub fn thread_override() -> usize {
    THREAD_OVERRIDE.load(Ordering::Relaxed)
}

/// The effective kernel thread budget.
pub fn threads() -> usize {
    if cfg!(not(feature = "parallel")) {
        return 1;
    }
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o != 0 {
        o
    } else {
        auto_threads()
    }
}

/// Host budget from `RAYON_NUM_THREADS` / `SPEED_NUM_THREADS`, else the
/// available hardware parallelism.
fn auto_threads() -> usize {
    static AUTO: OnceLock<usize> = OnceLock::new();
    *AUTO.get_or_init(|| {
        for key in ["RAYON_NUM_THREADS", "SPEED_NUM_THREADS"] {
            if let Some(n) = std::env::var(key).ok().and_then(|v| v.parse::<usize>().ok()) {
                if n > 0 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Split the host budget evenly across `nworkers` PAC workers (each worker
/// runs its own model, so the per-worker kernel budget is the quotient).
pub fn configure_for_workers(nworkers: usize) {
    set_threads((auto_threads() / nworkers.max(1)).max(1));
}

/// Minimum per-kernel volume (`m·k·n` multiply-adds) before a single
/// matmul call spreads across threads; below this the spawn overhead
/// dominates and the call stays serial on the caller's thread.
#[cfg(feature = "parallel")]
const PAR_MIN_WORK: usize = 1 << 16;

/// Whether the current thread is executing one of the [`join2`]/[`join3`]
/// role tasks. Matmuls inside a role stay serial so role-level and
/// matmul-level parallelism never multiply past the budget; the flag is
/// per-thread, so one worker's roles never throttle another worker's
/// kernels (unlike a process-global counter would).
#[cfg(feature = "parallel")]
thread_local! {
    static IN_FORK_TASK: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Run `f` with this thread marked as a fork task.
#[cfg(feature = "parallel")]
fn run_fork_task<T>(f: impl FnOnce() -> T) -> T {
    IN_FORK_TASK.with(|c| {
        let prev = c.replace(true);
        let out = f();
        c.set(prev);
        out
    })
}

#[cfg(feature = "parallel")]
fn plan_threads(units: usize, work: usize) -> usize {
    if work < PAR_MIN_WORK || units <= 1 || IN_FORK_TASK.with(std::cell::Cell::get) {
        return 1;
    }
    threads().min(units)
}

/// The kernel spawn policy, exported so fused composite ops (the attention
/// softmax+context stage in `kernels.rs`) can row-split with exactly the
/// same budget/threshold/fork-suppression rules as the matmuls. Always `1`
/// without the `parallel` feature.
pub fn plan_split(units: usize, work: usize) -> usize {
    #[cfg(feature = "parallel")]
    {
        plan_threads(units, work)
    }
    #[cfg(not(feature = "parallel"))]
    {
        let _ = (units, work);
        1
    }
}

// -- fork/join over role-level tasks ---------------------------------------

/// Run two independent tasks, concurrently when the budget allows.
/// Results are bit-identical either way (the tasks share no state).
pub fn join2<A, B, FA, FB>(fa: FA, fb: FB) -> (A, B)
where
    A: Send,
    B: Send,
    FA: FnOnce() -> A + Send,
    FB: FnOnce() -> B + Send,
{
    #[cfg(feature = "parallel")]
    if threads() > 1 {
        return std::thread::scope(|s| {
            let hb = s.spawn(|| run_fork_task(fb));
            let a = run_fork_task(fa);
            (a, hb.join().expect("parallel kernel task panicked"))
        });
    }
    (fa(), fb())
}

/// Run three independent tasks (the src/dst/neg attention roles),
/// concurrently when the budget allows.
pub fn join3<A, B, C, FA, FB, FC>(fa: FA, fb: FB, fc: FC) -> (A, B, C)
where
    A: Send,
    B: Send,
    C: Send,
    FA: FnOnce() -> A + Send,
    FB: FnOnce() -> B + Send,
    FC: FnOnce() -> C + Send,
{
    #[cfg(feature = "parallel")]
    if threads() > 1 {
        return std::thread::scope(|s| {
            let hb = s.spawn(|| run_fork_task(fb));
            let hc = s.spawn(|| run_fork_task(fc));
            let a = run_fork_task(fa);
            (
                a,
                hb.join().expect("parallel kernel task panicked"),
                hc.join().expect("parallel kernel task panicked"),
            )
        });
    }
    (fa(), fb(), fc())
}

// -- compute-precision dispatch --------------------------------------------

static F32_COMPUTE: AtomicBool = AtomicBool::new(true);

/// Toggle the f32 lane kernels at runtime. Only observable in builds with
/// the `simd` cargo feature — the default build always runs the f64 path.
/// The bench binary uses this to time both compute paths from one build;
/// everything else leaves it at the default (on).
pub fn set_f32_compute(on: bool) {
    F32_COMPUTE.store(on, Ordering::Relaxed);
}

/// Whether the matmul entry points dispatch to the f32 lane kernels:
/// compiled in by the `simd` cargo feature and enabled at runtime (the
/// default). Callers that need the exact f64 bit pattern regardless of
/// features use [`matmul_into_f64`] / [`matmul_a_bt_into_f64`] directly.
#[inline]
pub fn f32_compute() -> bool {
    cfg!(feature = "simd") && F32_COMPUTE.load(Ordering::Relaxed)
}

// -- blocked dense kernels -------------------------------------------------

/// `C[m,n] = A[m,k] · B[k,n]`, overwriting `c`. Row-parallel under the
/// `parallel` feature (each output row is computed identically regardless
/// of the split, so results never depend on the thread count). Dispatches
/// to the f32 lane kernels when [`f32_compute`] is on; `ws` backs their
/// narrowed-operand scratch and is untouched on the f64 path.
pub fn matmul_into(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f64],
    ws: &Workspace,
) {
    if f32_compute() {
        matmul_into_f32(a, b, m, k, n, c, ws);
        return;
    }
    matmul_into_f64(a, b, m, k, n, c);
}

/// The exact-f64 path of [`matmul_into`] — bit-identical to the seed
/// kernel on every input, with or without the `simd`/`parallel` features
/// (invariant 9).
pub fn matmul_into_f64(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    #[cfg(feature = "parallel")]
    {
        let nt = plan_threads(m, m * k * n);
        if nt > 1 {
            let rows = m.div_ceil(nt);
            std::thread::scope(|s| {
                for (ci, cchunk) in c.chunks_mut(rows * n).enumerate() {
                    let nrows = cchunk.len() / n;
                    let achunk = &a[ci * rows * k..ci * rows * k + nrows * k];
                    s.spawn(move || matmul_rows(achunk, b, k, n, cchunk));
                }
            });
            return;
        }
    }
    matmul_rows(a, b, k, n, c);
}

/// The per-row-range worker of [`matmul_into_f64`]: a 4-way unrolled
/// accumulate-over-k panel kernel.
fn matmul_rows(a: &[f64], b: &[f64], k: usize, n: usize, c: &mut [f64]) {
    if k == 0 {
        c.fill(0.0);
        return;
    }
    for (arow, crow) in a.chunks_exact(k).zip(c.chunks_exact_mut(n)) {
        crow.fill(0.0);
        let mut p = 0usize;
        while p + 4 <= k {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            p += 4;
        }
        while p < k {
            let ap = arow[p];
            if ap != 0.0 {
                let brow = &b[p * n..(p + 1) * n];
                for (cj, &bj) in crow.iter_mut().zip(brow) {
                    *cj += ap * bj;
                }
            }
            p += 1;
        }
    }
}

/// `C[m,k] = A[m,n] · Bᵀ` with `B[k,n]` — the input-gradient contraction.
/// Overwrites `c`; row-parallel like [`matmul_into`], with the same
/// [`f32_compute`] dispatch.
pub fn matmul_a_bt_into(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f64],
    ws: &Workspace,
) {
    if f32_compute() {
        a_bt_f32(a, b, m, k, n, c, ws);
        return;
    }
    matmul_a_bt_into_f64(a, b, m, k, n, c);
}

/// The exact-f64 path of [`matmul_a_bt_into`] (invariant 9).
pub fn matmul_a_bt_into_f64(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    if m == 0 || k == 0 {
        return;
    }
    if n == 0 {
        c.fill(0.0);
        return;
    }
    #[cfg(feature = "parallel")]
    {
        let nt = plan_threads(m, m * k * n);
        if nt > 1 {
            let rows = m.div_ceil(nt);
            std::thread::scope(|s| {
                for (ci, cchunk) in c.chunks_mut(rows * k).enumerate() {
                    let nrows = cchunk.len() / k;
                    let achunk = &a[ci * rows * n..ci * rows * n + nrows * n];
                    s.spawn(move || a_bt_rows(achunk, b, k, n, cchunk));
                }
            });
            return;
        }
    }
    a_bt_rows(a, b, k, n, c);
}

fn a_bt_rows(a: &[f64], b: &[f64], k: usize, n: usize, c: &mut [f64]) {
    for (arow, crow) in a.chunks_exact(n).zip(c.chunks_exact_mut(k)) {
        for (cp, brow) in crow.iter_mut().zip(b.chunks_exact(n)) {
            *cp = dot(arow, brow);
        }
    }
}

/// 4-lane unrolled dot product with a deterministic reduction order
/// (depends only on the vector length, never on threading). This is the
/// f64 path's reduction primitive and is deliberately untouched by the
/// `simd` feature — its lane twin is [`dot_f32`].
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut xc = x.chunks_exact(4);
    let mut yc = y.chunks_exact(4);
    for (xq, yq) in (&mut xc).zip(&mut yc) {
        s0 += xq[0] * yq[0];
        s1 += xq[1] * yq[1];
        s2 += xq[2] * yq[2];
        s3 += xq[3] * yq[3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for (xr, yr) in xc.remainder().iter().zip(yc.remainder()) {
        s += xr * yr;
    }
    s
}

/// Fixed row-block size of the `AᵀB` reduction. Split points depend only
/// on `m`, so the serial and parallel paths fold the same per-block
/// partials in the same order — bit-identical results by construction.
const AT_B_BLOCK: usize = 128;

/// `C[k,n] = Aᵀ · B` with `A[m,k]`, `B[m,n]` — the weight-gradient
/// contraction. Overwrites `c`. The contraction over `m` runs in fixed
/// blocks of [`AT_B_BLOCK`] rows whose partial sums fold in block order;
/// under the `parallel` feature the blocks compute concurrently
/// (per-block accumulation, no atomic reduction). Dispatches to the f32
/// lane path when [`f32_compute`] is on, with the same fixed-block fold.
pub fn matmul_at_b_into(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f64],
    ws: &Workspace,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    c.fill(0.0);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let nblocks = m.div_ceil(AT_B_BLOCK);
    if f32_compute() {
        at_b_f32(a, b, m, k, n, c, nblocks, ws);
        return;
    }
    #[cfg(feature = "parallel")]
    {
        let nt = plan_threads(nblocks, m * k * n);
        if nt > 1 {
            let mut partials: Vec<Vec<f64>> = (1..nblocks).map(|_| ws.take(k * n)).collect();
            // Blocks 1.. split into nt-1 contiguous groups (block 0 runs on
            // this thread), so at most nt threads are live — the budget is
            // respected while every block keeps its own partial, which is
            // what preserves the serial fold order.
            let per = (nblocks - 1).div_ceil(nt - 1);
            std::thread::scope(|s| {
                for (gi, group) in partials.chunks_mut(per).enumerate() {
                    let first = 1 + gi * per;
                    s.spawn(move || {
                        for (off, partial) in group.iter_mut().enumerate() {
                            let i0 = (first + off) * AT_B_BLOCK;
                            at_b_block(a, b, k, n, i0, (i0 + AT_B_BLOCK).min(m), partial);
                        }
                    });
                }
                at_b_block(a, b, k, n, 0, AT_B_BLOCK, c);
            });
            for partial in &partials {
                for (cj, &pj) in c.iter_mut().zip(partial) {
                    *cj += pj;
                }
            }
            for partial in partials {
                ws.give(partial);
            }
            return;
        }
    }
    // Serial: the identical fixed-block left fold.
    at_b_block(a, b, k, n, 0, AT_B_BLOCK.min(m), c);
    if nblocks > 1 {
        let mut partial = ws.take(k * n);
        for blk in 1..nblocks {
            partial.fill(0.0);
            let i0 = blk * AT_B_BLOCK;
            at_b_block(a, b, k, n, i0, (i0 + AT_B_BLOCK).min(m), &mut partial);
            for (cj, &pj) in c.iter_mut().zip(partial.iter()) {
                *cj += pj;
            }
        }
        ws.give(partial);
    }
}

/// `c[k,n] += Σ_{i∈[i0,i1)} a[i,·]ᵀ ⊗ b[i,·]` — one reduction block.
fn at_b_block(a: &[f64], b: &[f64], k: usize, n: usize, i0: usize, i1: usize, c: &mut [f64]) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (p, &aip) in arow.iter().enumerate() {
            if aip == 0.0 {
                continue;
            }
            let crow = &mut c[p * n..(p + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow) {
                *cj += aip * bj;
            }
        }
    }
}

// -- f32 lane kernels (the `simd` feature's compute path) -------------------

/// Lane width of the f32 kernels. Plain indexed loops over chunks of this
/// width compile to packed single-precision vector ops on stable rustc
/// (no `std::simd`): 8 f32 lanes fill one AVX2 register.
const F32_LANES: usize = 8;

/// Depth of one f32 accumulation block: products accumulate in f32 lanes
/// for at most this many k-steps before the block total folds into the
/// f64 output, which bounds the f32 round-off independently of the
/// contraction depth. [`dot_f32`] uses the same depth with a pairwise
/// lane fold.
const F32_KBLOCK: usize = 64;

/// Refill `dst` (a pooled f32 buffer of matching length) with the f32
/// narrowing of `src`. `clear` + `extend` reuses the allocation.
pub(super) fn load32(dst: &mut Vec<f32>, src: &[f64]) {
    dst.clear();
    dst.extend(src.iter().map(|&x| x as f32));
}

/// The f32 lane path of [`matmul_into`]: both operands narrow to pooled
/// f32 buffers once per call, every output row accumulates in f32 lanes
/// within [`F32_KBLOCK`]-deep k-blocks, and block totals fold into the
/// f64 output row. Row-parallel with the same fixed split as the f64 path
/// and per-row math that never depends on the split, so — like every
/// kernel here — results are invariant to the thread count.
fn matmul_into_f32(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f64],
    ws: &Workspace,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let mut a32 = ws.take32_full(m * k);
    load32(&mut a32, a);
    let mut b32 = ws.take32_full(k * n);
    load32(&mut b32, b);
    let (a32s, b32s): (&[f32], &[f32]) = (&a32, &b32);
    #[cfg(feature = "parallel")]
    {
        let nt = plan_threads(m, m * k * n);
        if nt > 1 {
            let rows = m.div_ceil(nt);
            std::thread::scope(|s| {
                for (ci, cchunk) in c.chunks_mut(rows * n).enumerate() {
                    let nrows = cchunk.len() / n;
                    let achunk = &a32s[ci * rows * k..ci * rows * k + nrows * k];
                    s.spawn(move || {
                        let mut acc = ws.take32_full(n);
                        matmul_rows_f32(achunk, b32s, k, n, cchunk, &mut acc);
                        ws.give32(acc);
                    });
                }
            });
            ws.give32(a32);
            ws.give32(b32);
            return;
        }
    }
    let mut acc = ws.take32_full(n);
    matmul_rows_f32(a32s, b32s, k, n, c, &mut acc);
    ws.give32(acc);
    ws.give32(a32);
    ws.give32(b32);
}

/// The per-row-range worker of [`matmul_into_f32`]. `acc` is one output
/// row's worth of f32 lanes, reset per k-block; each block's total folds
/// into the f64 row before the next block starts.
fn matmul_rows_f32(a: &[f32], b: &[f32], k: usize, n: usize, c: &mut [f64], acc: &mut [f32]) {
    debug_assert_eq!(acc.len(), n);
    for (arow, crow) in a.chunks_exact(k).zip(c.chunks_exact_mut(n)) {
        crow.fill(0.0);
        let mut p0 = 0usize;
        while p0 < k {
            let p1 = (p0 + F32_KBLOCK).min(k);
            acc.fill(0.0);
            for p in p0..p1 {
                let ap = arow[p];
                if ap == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                let mut av = acc.chunks_exact_mut(F32_LANES);
                let mut bv = brow.chunks_exact(F32_LANES);
                for (aq, bq) in (&mut av).zip(&mut bv) {
                    for l in 0..F32_LANES {
                        aq[l] += ap * bq[l];
                    }
                }
                for (aj, &bj) in av.into_remainder().iter_mut().zip(bv.remainder()) {
                    *aj += ap * bj;
                }
            }
            for (cj, &aj) in crow.iter_mut().zip(acc.iter()) {
                *cj += f64::from(aj);
            }
            p0 = p1;
        }
    }
}

/// Lane dot product over f32 operands with f64 block accumulation: within
/// each [`F32_KBLOCK`]-deep block, products accumulate in [`F32_LANES`]
/// f32 lanes that reduce by a pairwise fold; block totals sum in f64.
/// Relative error on random 512-dim inputs stays below 1e-5 (asserted in
/// this module's tests), comfortably inside the golden fixtures' 1e-4
/// contract.
#[inline]
pub fn dot_f32(x: &[f32], y: &[f32]) -> f64 {
    let mut total = 0.0f64;
    for (xb, yb) in x.chunks(F32_KBLOCK).zip(y.chunks(F32_KBLOCK)) {
        let mut lanes = [0.0f32; F32_LANES];
        let mut xc = xb.chunks_exact(F32_LANES);
        let mut yc = yb.chunks_exact(F32_LANES);
        for (xq, yq) in (&mut xc).zip(&mut yc) {
            for l in 0..F32_LANES {
                lanes[l] += xq[l] * yq[l];
            }
        }
        let mut tail = 0.0f32;
        for (xr, yr) in xc.remainder().iter().zip(yc.remainder()) {
            tail += xr * yr;
        }
        let block = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
            + tail;
        total += f64::from(block);
    }
    total
}

/// The f32 lane path of [`matmul_a_bt_into`]: narrow both operands once,
/// then row-parallel [`dot_f32`] contractions (same split policy as the
/// f64 path).
fn a_bt_f32(a: &[f64], b: &[f64], m: usize, k: usize, n: usize, c: &mut [f64], ws: &Workspace) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    if m == 0 || k == 0 {
        return;
    }
    if n == 0 {
        c.fill(0.0);
        return;
    }
    let mut a32 = ws.take32_full(m * n);
    load32(&mut a32, a);
    let mut b32 = ws.take32_full(k * n);
    load32(&mut b32, b);
    let (a32s, b32s): (&[f32], &[f32]) = (&a32, &b32);
    #[cfg(feature = "parallel")]
    {
        let nt = plan_threads(m, m * k * n);
        if nt > 1 {
            let rows = m.div_ceil(nt);
            std::thread::scope(|s| {
                for (ci, cchunk) in c.chunks_mut(rows * k).enumerate() {
                    let nrows = cchunk.len() / k;
                    let achunk = &a32s[ci * rows * n..ci * rows * n + nrows * n];
                    s.spawn(move || a_bt_rows_f32(achunk, b32s, k, n, cchunk));
                }
            });
            ws.give32(a32);
            ws.give32(b32);
            return;
        }
    }
    a_bt_rows_f32(a32s, b32s, k, n, c);
    ws.give32(a32);
    ws.give32(b32);
}

fn a_bt_rows_f32(a: &[f32], b: &[f32], k: usize, n: usize, c: &mut [f64]) {
    for (arow, crow) in a.chunks_exact(n).zip(c.chunks_exact_mut(k)) {
        for (cp, brow) in crow.iter_mut().zip(b.chunks_exact(n)) {
            *cp = dot_f32(arow, brow);
        }
    }
}

/// The f32 lane path of the `AᵀB` reduction: every [`AT_B_BLOCK`] row
/// block accumulates an f32 partial that folds into the f64 output in
/// strict block-index order — the same fixed fold as the f64 path, so
/// serial and parallel runs stay bit-identical to each other. `c` must
/// arrive zero-filled (the dispatching caller clears it).
#[allow(clippy::too_many_arguments)]
fn at_b_f32(
    a: &[f64],
    b: &[f64],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f64],
    nblocks: usize,
    ws: &Workspace,
) {
    let mut a32 = ws.take32_full(m * k);
    load32(&mut a32, a);
    let mut b32 = ws.take32_full(m * n);
    load32(&mut b32, b);
    let (a32s, b32s): (&[f32], &[f32]) = (&a32, &b32);
    #[cfg(feature = "parallel")]
    {
        let nt = plan_threads(nblocks, m * k * n);
        if nt > 1 {
            let mut partials: Vec<Vec<f32>> = (0..nblocks).map(|_| ws.take32(k * n)).collect();
            let per = nblocks.div_ceil(nt);
            std::thread::scope(|s| {
                for (gi, group) in partials.chunks_mut(per).enumerate() {
                    let first = gi * per;
                    s.spawn(move || {
                        for (off, partial) in group.iter_mut().enumerate() {
                            let i0 = (first + off) * AT_B_BLOCK;
                            at_b_block_f32(a32s, b32s, k, n, i0, (i0 + AT_B_BLOCK).min(m), partial);
                        }
                    });
                }
            });
            for partial in &partials {
                for (cj, &pj) in c.iter_mut().zip(partial) {
                    *cj += f64::from(pj);
                }
            }
            for partial in partials {
                ws.give32(partial);
            }
            ws.give32(a32);
            ws.give32(b32);
            return;
        }
    }
    // Serial: identical per-block partials folded in the same order.
    let mut partial = ws.take32(k * n);
    for blk in 0..nblocks {
        if blk > 0 {
            partial.fill(0.0);
        }
        let i0 = blk * AT_B_BLOCK;
        at_b_block_f32(a32s, b32s, k, n, i0, (i0 + AT_B_BLOCK).min(m), &mut partial);
        for (cj, &pj) in c.iter_mut().zip(partial.iter()) {
            *cj += f64::from(pj);
        }
    }
    ws.give32(partial);
    ws.give32(a32);
    ws.give32(b32);
}

/// f32 twin of [`at_b_block`], with an 8-lane inner axpy. `AT_B_BLOCK`
/// (128 rows) doubles as the f32 accumulation bound here, matching the
/// [`F32_KBLOCK`] error budget of the forward kernels.
fn at_b_block_f32(a: &[f32], b: &[f32], k: usize, n: usize, i0: usize, i1: usize, c: &mut [f32]) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (p, &aip) in arow.iter().enumerate() {
            if aip == 0.0 {
                continue;
            }
            let crow = &mut c[p * n..(p + 1) * n];
            let mut cv = crow.chunks_exact_mut(F32_LANES);
            let mut bv = brow.chunks_exact(F32_LANES);
            for (cq, bq) in (&mut cv).zip(&mut bv) {
                for l in 0..F32_LANES {
                    cq[l] += aip * bq[l];
                }
            }
            for (cj, &bj) in cv.into_remainder().iter_mut().zip(bv.remainder()) {
                *cj += aip * bj;
            }
        }
    }
}

// -- allocating conveniences (test-only) -----------------------------------
//
// Vec-returning wrappers are a hot-path-alloc trap for shipped callers
// (everything real goes through the `_into` kernels + Workspace), so they
// are compiled only for tests — here and in kernels.rs unit tests.

#[cfg(test)]
/// `C[m,n] = A[m,k] · B[k,n]`, freshly allocated.
pub(crate) fn matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let ws = Workspace::new();
    let mut c = vec![0.0; m * n];
    matmul_into(a, b, m, k, n, &mut c, &ws);
    c
}

#[cfg(test)]
/// `C[k,n] = Aᵀ · B` with `A[m,k]`, `B[m,n]`, freshly allocated.
pub(crate) fn matmul_at_b(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let ws = Workspace::new();
    let mut c = vec![0.0; k * n];
    matmul_at_b_into(a, b, m, k, n, &mut c, &ws);
    c
}

#[cfg(test)]
/// `C[m,k] = A[m,n] · Bᵀ` with `B[k,n]`, freshly allocated.
pub(crate) fn matmul_a_bt(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let ws = Workspace::new();
    let mut c = vec![0.0; m * k];
    matmul_a_bt_into(a, b, m, k, n, &mut c, &ws);
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg_vec(n: usize, seed: &mut u64) -> Vec<f64> {
        (0..n)
            .map(|_| {
                *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((*seed >> 33) as f64 / (1u64 << 31) as f64) - 0.5
            })
            .collect()
    }

    fn naive_matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn workspace_recycles_buffers() {
        let ws = Workspace::new();
        let mut v = ws.take(64);
        v[0] = 3.5;
        let ptr = v.as_ptr();
        ws.give(v);
        let v2 = ws.take(64);
        assert_eq!(v2.as_ptr(), ptr, "same-length take must reuse the pooled buffer");
        assert!(v2.iter().all(|&x| x == 0.0), "recycled buffers are zeroed");
        assert_eq!(ws.pooled(), 0);
        ws.give(v2);
        assert_eq!(ws.pooled(), 1);
        // Different length does not alias.
        let w = ws.take(32);
        assert_eq!(ws.pooled(), 1);
        ws.give(w);
        // Copies land verbatim.
        let c = ws.take_copy(&[1.0, 2.0, 3.0]);
        assert_eq!(c, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn workspace_recycles_f32_lane_buffers() {
        let ws = Workspace::new();
        let mut v = ws.take32(48);
        v[0] = 2.5;
        let ptr = v.as_ptr();
        ws.give32(v);
        let v2 = ws.take32(48);
        assert_eq!(v2.as_ptr(), ptr, "same-length take32 must reuse the pooled buffer");
        assert!(v2.iter().all(|&x| x == 0.0), "recycled f32 buffers are zeroed");
        ws.give32(v2);
        // pooled() counts both element types.
        let d = ws.take(16);
        ws.give(d);
        assert_eq!(ws.pooled(), 2);
    }

    #[test]
    fn blocked_kernels_match_naive() {
        // The dispatching entry points run the build's default compute
        // path: exact f64 without the `simd` feature, f32 lanes with it
        // (held to the golden fixtures' relative-tolerance contract).
        let tol = if cfg!(feature = "simd") { 1e-5 } else { 1e-12 };
        let mut seed = 9u64;
        // Deliberately awkward shapes: remainders in every unroll.
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (7, 6, 2), (33, 13, 9)] {
            let a = lcg_vec(m * k, &mut seed);
            let b = lcg_vec(k * n, &mut seed);
            let want = naive_matmul(&a, &b, m, k, n);
            let got = matmul(&a, &b, m, k, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < tol, "matmul {m}x{k}x{n}");
            }

            // AᵀB via the naive kernel on the transposed operand.
            let at: Vec<f64> = (0..k * m)
                .map(|idx| {
                    let (p, i) = (idx / m, idx % m);
                    a[i * k + p]
                })
                .collect();
            let b2 = lcg_vec(m * n, &mut seed);
            let want = naive_matmul(&at, &b2, k, m, n);
            let got = matmul_at_b(&a, &b2, m, k, n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < tol, "at_b {m}x{k}x{n}");
            }

            // ABᵀ: c[i,p] = dot(a_row_i, b_row_p) with A[m,n], B[k,n].
            let a3 = lcg_vec(m * n, &mut seed);
            let b3 = lcg_vec(k * n, &mut seed);
            let got = matmul_a_bt(&a3, &b3, m, k, n);
            for i in 0..m {
                for p in 0..k {
                    let want: f64 =
                        (0..n).map(|j| a3[i * n + j] * b3[p * n + j]).sum();
                    assert!((got[i * k + p] - want).abs() < tol, "a_bt {m}x{k}x{n}");
                }
            }
        }
    }

    /// The f32 lane kernels are compiled in every build (the `simd`
    /// feature only flips their dispatch default), so this asserts the
    /// precision contract unconditionally: every f32 kernel stays within
    /// the golden fixtures' 1e-4 relative tolerance of the exact f64 path.
    #[test]
    fn f32_kernels_match_f64_reference() {
        fn assert_rel(got: &[f64], want: &[f64], what: &str) {
            for (g, w) in got.iter().zip(want) {
                assert!((g - w).abs() <= 1e-4 * (1.0 + w.abs()), "{what}: {g} vs {w}");
            }
        }
        let ws = Workspace::new();
        let mut seed = 77u64;
        // Shapes straddle the lane width, the k-block depth, and (for the
        // reduction) the AT_B_BLOCK row-block boundary.
        let shapes =
            [(1usize, 1usize, 1usize), (3, 5, 7), (33, 13, 9), (70, 100, 12), (300, 24, 16)];
        for &(m, k, n) in &shapes {
            let a = lcg_vec(m * k, &mut seed);
            let b = lcg_vec(k * n, &mut seed);
            let mut want = vec![0.0; m * n];
            matmul_into_f64(&a, &b, m, k, n, &mut want);
            let mut got = vec![0.0; m * n];
            matmul_into_f32(&a, &b, m, k, n, &mut got, &ws);
            assert_rel(&got, &want, "matmul_f32");

            let b2 = lcg_vec(m * n, &mut seed);
            let mut want = vec![0.0; k * n];
            at_b_block(&a, &b2, k, n, 0, m, &mut want);
            let mut got = vec![0.0; k * n];
            at_b_f32(&a, &b2, m, k, n, &mut got, m.div_ceil(AT_B_BLOCK), &ws);
            assert_rel(&got, &want, "at_b_f32");

            let a3 = lcg_vec(m * n, &mut seed);
            let b3 = lcg_vec(k * n, &mut seed);
            let mut want = vec![0.0; m * k];
            matmul_a_bt_into_f64(&a3, &b3, m, k, n, &mut want);
            let mut got = vec![0.0; m * k];
            a_bt_f32(&a3, &b3, m, k, n, &mut got, &ws);
            assert_rel(&got, &want, "a_bt_f32");
        }
    }

    /// The satellite accuracy contract for the lane reduction: random
    /// 512-dim dots on the f32 path stay below 1e-5 relative error vs the
    /// f64 reference. Positive uniform inputs so the relative error
    /// measures accumulation quality, not cancellation conditioning.
    #[test]
    fn dot_f32_accumulation_error_below_1e5_relative() {
        let mut seed = 2024u64;
        for case in 0..8 {
            let x: Vec<f64> = lcg_vec(512, &mut seed).iter().map(|v| v + 0.5).collect();
            let y: Vec<f64> = lcg_vec(512, &mut seed).iter().map(|v| v + 0.5).collect();
            let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let y32: Vec<f32> = y.iter().map(|&v| v as f32).collect();
            let want = dot(&x, &y);
            let rel = (dot_f32(&x32, &y32) - want).abs() / want.abs();
            assert!(rel < 1e-5, "case {case}: rel err {rel:.3e}");
        }
    }

    /// Budget plumbing and serial/parallel bit-identity live in ONE test:
    /// both manipulate the global thread override, and a single test body
    /// is the only way to keep them from racing each other under the
    /// multi-threaded test harness. Under `simd` the dispatching entry
    /// points run the f32 lane path, so this doubles as the proof that the
    /// f32 kernels are thread-count invariant too (invariant 9).
    #[test]
    fn thread_budget_and_bit_identity() {
        assert!(threads() >= 1);
        // An absurd worker count clamps the per-worker budget to 1.
        configure_for_workers(1_000_000);
        assert_eq!(threads(), 1);
        set_threads(0);

        // Multi-block shape (m > AT_B_BLOCK) with enough volume to clear
        // the parallel threshold when the feature is on.
        let (m, k, n) = (4 * AT_B_BLOCK + 17, 24, 16);
        let mut seed = 4u64;
        let a = lcg_vec(m * k, &mut seed);
        let b = lcg_vec(m * n, &mut seed);
        let ws = Workspace::new();
        let mut serial = vec![0.0; k * n];
        set_threads(1);
        matmul_at_b_into(&a, &b, m, k, n, &mut serial, &ws);
        let mut par = vec![0.0; k * n];
        set_threads(4);
        matmul_at_b_into(&a, &b, m, k, n, &mut par, &ws);
        set_threads(0);
        assert!(
            serial.iter().zip(&par).all(|(s, p)| s.to_bits() == p.to_bits()),
            "fixed-block fold must make the parallel path bit-identical"
        );

        // Row-parallel kernels: same property.
        let c1 = {
            set_threads(1);
            matmul(&a, &b[..k * n], m, k, n)
        };
        let c4 = {
            set_threads(4);
            matmul(&a, &b[..k * n], m, k, n)
        };
        set_threads(0);
        assert!(c1.iter().zip(&c4).all(|(s, p)| s.to_bits() == p.to_bits()));
    }

    #[test]
    fn join_runs_every_task() {
        let (a, b) = join2(|| 1 + 1, || "two");
        assert_eq!((a, b), (2, "two"));
        let (x, y, z) = join3(|| vec![1], || vec![2, 2], || 3.0);
        assert_eq!(x, vec![1]);
        assert_eq!(y, vec![2, 2]);
        assert_eq!(z, 3.0);
    }
}
