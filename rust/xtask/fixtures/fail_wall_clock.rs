//! Fixture: raw `std::time::Instant` in a deterministic module. Results
//! must be a pure function of (input, seed); observability timing goes
//! through `util::Stopwatch`. Must trip `wall-clock`.

use std::time::Instant;

pub fn spill_if_slow(budget_ms: u128, work: impl FnOnce()) -> bool {
    let t0 = Instant::now();
    work();
    // Time-dependent control flow: identical inputs, different outputs.
    t0.elapsed().as_millis() > budget_ms
}
