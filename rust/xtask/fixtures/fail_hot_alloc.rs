//! Fixture: heap allocation two calls below a hot root. The warm train
//! step must draw every buffer from the Workspace arena (PR 2 contract);
//! reachability from `step` must find the `Vec::new` in `helper_two`.
//! Must trip `hot-path-alloc`.

pub fn step(xs: &[f64], out: &mut [f64]) {
    helper_one(xs, out);
}

fn helper_one(xs: &[f64], out: &mut [f64]) {
    let extra = helper_two(xs);
    for (o, e) in out.iter_mut().zip(extra.iter()) {
        *o += e;
    }
}

fn helper_two(xs: &[f64]) -> Vec<f64> {
    let mut v = Vec::new();
    for &x in xs {
        v.push(x * 2.0);
    }
    v
}
