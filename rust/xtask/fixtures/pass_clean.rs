//! Fixture: a deterministic-module file that exercises every rule's happy
//! path — ordered collections, a justified inline allow, a SAFETY'd unsafe
//! block, lock taken outside the loop — and must produce zero violations.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

pub fn degree_histogram(edges: &[(u32, u32)]) -> Vec<(u32, u32)> {
    let mut deg: BTreeMap<u32, u32> = BTreeMap::new();
    for &(a, b) in edges {
        *deg.entry(a).or_insert(0) += 1;
        *deg.entry(b).or_insert(0) += 1;
    }
    deg.into_iter().collect()
}

pub fn dedup(xs: &[u32]) -> BTreeSet<u32> {
    xs.iter().copied().collect()
}

// Membership-only map, never iterated — the justified escape hatch.
// lint:allow(nondet-collection): membership-only cache, never iterated
pub type SeenCache = std::collections::HashSet<u64>;

pub fn accumulate(items: &[f64], total: &Mutex<f64>) {
    let mut guard = total.lock().expect("poisoned");
    for &x in items {
        *guard += x;
    }
}

pub fn tail_u32(bytes: &[u8]) -> u32 {
    let mut buf = [0u8; 4];
    buf.copy_from_slice(&bytes[bytes.len() - 4..]);
    // SAFETY comments satisfy the hygiene rule even for trivially sound
    // blocks; this one reads a fully-initialized stack array.
    // SAFETY: `buf` is 4 initialized bytes; transmuting to u32 is sound.
    let v = unsafe { std::mem::transmute::<[u8; 4], u32>(buf) };
    u32::from_le(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap; // exempt: below the cfg(test) cutoff

    #[test]
    fn histogram_counts() {
        let h = degree_histogram(&[(0, 1), (1, 2)]);
        let m: HashMap<u32, u32> = h.into_iter().collect();
        assert_eq!(m[&1], 2);
    }
}
