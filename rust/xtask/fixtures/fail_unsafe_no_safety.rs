//! Fixture: an `unsafe` block with no safety comment within 3 lines
//! above. Every unsafe block must state the invariant that makes it
//! sound. Must trip `unsafe-needs-safety`.

pub fn as_bytes(xs: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr().cast::<u8>(), xs.len() * 4) }
}
