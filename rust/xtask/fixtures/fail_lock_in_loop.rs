//! Fixture: a Mutex lock inside a per-item loop in a deterministic module.
//! Per-step locking is a contention and ordering hazard; it must be an
//! explicit, justified decision (bounded critical section, barrier-ordered)
//! — not something that slips in. Must trip `lock-in-loop`.

use std::sync::Mutex;

pub fn accumulate(items: &[f64], total: &Mutex<f64>) {
    for &x in items {
        let mut guard = total.lock().expect("poisoned");
        *guard += x;
    }
}
