//! Fixture: host-core-count-dependent behavior in a deterministic module.
//! Worker counts shape batch group boundaries, so deriving them from
//! `available_parallelism` makes results machine-dependent. The kernel
//! thread budget lives in `backend::native::tensor` (outside the
//! deterministic set) and never changes results. Must trip
//! `ambient-parallelism`.

pub fn pick_worker_count() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
