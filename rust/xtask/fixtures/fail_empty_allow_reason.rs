//! Fixture: a `lint:allow` marker with no justification. The escape hatch
//! is audited — an allow without a reason is itself a violation.

use std::collections::HashMap; // lint:allow(nondet-collection)

pub fn lookup_only(m: &HashMap<u32, u32>, k: u32) -> Option<u32> {
    m.get(&k).copied()
}
