//! Fixture: ambient (OS-entropy) randomness. Every RNG in this repo is a
//! seeded `util::Rng` so runs are replayable; `thread_rng`-style sources
//! are banned everywhere, not just deterministic modules. Must trip
//! `ambient-rng`.

pub fn sample_negatives(n: usize) -> Vec<u32> {
    let mut rng = thread_rng();
    (0..n).map(|_| rng.next_u32()).collect()
}

fn thread_rng() -> Dummy {
    Dummy
}

struct Dummy;
impl Dummy {
    fn next_u32(&mut self) -> u32 {
        0
    }
}
