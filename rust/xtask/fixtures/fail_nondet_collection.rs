//! Fixture: HashMap in a deterministic module. Iteration order is
//! RandomState-seeded per process, so anything derived from it (CSR layout,
//! BFS seed order, ...) varies across runs. Must trip `nondet-collection`.

use std::collections::HashMap;

pub fn degree_histogram(edges: &[(u32, u32)]) -> Vec<(u32, u32)> {
    let mut deg: HashMap<u32, u32> = HashMap::new();
    for &(a, b) in edges {
        *deg.entry(a).or_insert(0) += 1;
        *deg.entry(b).or_insert(0) += 1;
    }
    // The bug this lint exists to catch: iteration order leaks into output.
    deg.into_iter().collect()
}
