//! `speed-lint`: the repo-specific invariant pass behind `cargo xtask lint`.
//!
//! SPEED's correctness story rests on invariants the compiler cannot see
//! (docs/INVARIANTS.md): parallel PAC training must stay bit-identical to
//! serial, streaming must stay byte-identical to resident, and warm train
//! steps must stay alloc-free. A stray `HashMap` iteration, a
//! `thread_rng()`, or a `Vec::new()` in a kernel silently breaks those
//! contracts until a parity fixture catches it — or doesn't. This pass
//! makes them machine-checked at the source level on every push.
//!
//! The implementation is a token-level scan over comment/string-stripped
//! source (dependency-free by design — the container that builds this repo
//! has no crates.io access, so a `syn` AST walk is not on the table). That
//! buys exhaustiveness over cleverness: rules are match-by-name, and the
//! escape hatches are explicit and audited:
//!
//! * an inline `// lint:allow(rule): reason` marker on (or directly above)
//!   the offending line — the reason string is mandatory;
//! * an entry in `rust/xtask/allowlist.txt` scoped to (rule, file, fn),
//!   also with a mandatory justification. Stale entries fail the lint, so
//!   the allowlist can only shrink unless a human re-justifies it.
//!
//! Rules (ids are what `lint:allow(..)` and the allowlist reference):
//!
//! | id                    | scope                    | forbids                                   |
//! |-----------------------|--------------------------|-------------------------------------------|
//! | `nondet-collection`   | deterministic modules    | `HashMap` / `HashSet` (use `BTreeMap`/`BTreeSet`) |
//! | `wall-clock`          | deterministic modules    | `std::time::{Instant, SystemTime}` (use `util::Stopwatch` for observability) |
//! | `ambient-rng`         | everywhere in `rust/src` | `thread_rng` / `ThreadRng` / `from_entropy` (use seeded `util::Rng`) |
//! | `ambient-parallelism` | deterministic modules    | `thread::available_parallelism` (budget lives in `backend::native::tensor`) |
//! | `hot-path-alloc`      | fns reachable from `model::step` / `*_step_into` inside `backend/native` | `Vec::new`, `vec!`, `with_capacity`, `to_vec`, `Box::new`, `format!`, `String::new`, `to_string`, `to_owned`, `collect`, `clone` |
//! | `unsafe-needs-safety` | everywhere in `rust/src` | `unsafe` without a `// SAFETY:` comment within 5 lines above |
//! | `lock-in-loop`        | deterministic modules + `backend/native` | `.lock(` lexically inside a `for`/`while`/`loop` body |
//!
//! Code at or below the file's first `#[cfg(test)]` line is exempt (the
//! repo convention keeps unit tests last in the file); determinism and
//! arena contracts bind shipped code, not assertions about it.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::Path;

// ---------------------------------------------------------------------------
// Rule configuration
// ---------------------------------------------------------------------------

/// Every rule id this pass can emit (also the vocabulary of
/// `lint:allow(..)` markers and allowlist entries).
pub const RULE_IDS: &[&str] = &[
    "nondet-collection",
    "wall-clock",
    "ambient-rng",
    "ambient-parallelism",
    "hot-path-alloc",
    "unsafe-needs-safety",
    "lock-in-loop",
];

/// Modules whose output must be a pure function of (input, seed): the
/// streaming partitioner, the graph/split substrate, the out-of-core data
/// plane, the streaming monitor (whose tick stream is diffed bit-for-bit
/// in CI — invariant 11), and the deterministic coordinator stages. Paths
/// are relative to `rust/src/`; a trailing `/` scopes a whole directory.
const DETERMINISTIC_MODULES: &[&str] = &[
    "sep/",
    "graph/",
    "data/",
    "monitor/",
    "coordinator/batcher.rs",
    "coordinator/trainer.rs",
    "coordinator/subgraph.rs",
    "coordinator/evaluator.rs",
];

/// The files whose functions participate in hot-path reachability — the
/// native backend's kernel/arena/model layer. The arena contract (PR 2)
/// lives entirely inside this directory.
const HOT_UNIVERSE: &[&str] = &[
    "backend/native/kernels.rs",
    "backend/native/model.rs",
    "backend/native/tensor.rs",
    "backend/native/mod.rs",
];

/// Reachability roots: the per-step entry points. Everything these call
/// (transitively, by name, within the universe) is "hot".
const HOT_ROOTS: &[&str] = &["step", "train_step_into", "eval_step_into"];

/// Heap-allocating (or alloc-adjacent) calls forbidden in hot functions.
/// Substring patterns over stripped source; `vec!` also catches `vec![..]`.
const ALLOC_PATTERNS: &[&str] = &[
    "Vec::new(",
    "vec!",
    "with_capacity(",
    ".to_vec(",
    "Box::new(",
    "format!",
    "String::new(",
    ".to_string(",
    ".to_owned(",
    ".collect(",
    ".clone(",
];

/// Idents that would create false call-graph edges: `Box::new`/`Vec::new`
/// resolve to the callee name `new`, which would drag every constructor in
/// the universe into the hot set.
const CALL_EDGE_EXCLUDED: &[&str] = &["new"];

/// Keywords that precede `(` without being calls.
const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "in", "as", "let", "mut", "ref",
    "move", "fn", "pub", "unsafe", "else", "impl", "dyn", "where", "use", "crate",
    "super", "self", "Self", "break", "continue",
];

fn in_deterministic_module(rel: &str) -> bool {
    DETERMINISTIC_MODULES.iter().any(|m| {
        if let Some(dir) = m.strip_suffix('/') {
            rel.starts_with(dir) && rel[dir.len()..].starts_with('/')
        } else {
            rel == *m
        }
    })
}

fn in_hot_universe(rel: &str) -> bool {
    // Exact files plus anything else under backend/native/ (so a new file
    // in the kernel layer is in scope by default, not by remembering to
    // list it).
    HOT_UNIVERSE.contains(&rel) || rel.starts_with("backend/native/")
}

// ---------------------------------------------------------------------------
// Violations and the allowlist
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Display path (`rust/src/...` or `rust/xtask/allowlist.txt`).
    pub path: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// One `rule | file | fn | justification` grant from `allowlist.txt`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub rule: String,
    /// Path relative to `rust/src/`.
    pub file: String,
    /// Function name, or `*` for anywhere in the file.
    pub func: String,
    pub reason: String,
    /// Line in allowlist.txt (for stale-entry diagnostics).
    pub line: usize,
}

/// Parse `allowlist.txt`. Errors are returned as violations against the
/// allowlist file itself so they surface exactly like lint findings.
pub fn parse_allowlist(text: &str, display_path: &str) -> (Vec<AllowEntry>, Vec<Violation>) {
    let mut entries = Vec::new();
    let mut errs = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.splitn(4, '|').map(str::trim).collect();
        let mut err = |msg: String| {
            errs.push(Violation {
                path: display_path.to_string(),
                line: i + 1,
                rule: "allowlist",
                msg,
            });
        };
        if parts.len() != 4 {
            err("expected `rule | file | fn | justification`".to_string());
            continue;
        }
        let (rule, file, func, reason) = (parts[0], parts[1], parts[2], parts[3]);
        if !RULE_IDS.contains(&rule) {
            err(format!("unknown rule {rule:?} (known: {RULE_IDS:?})"));
            continue;
        }
        if reason.is_empty() {
            err(format!("entry for {rule} on {file} has an empty justification"));
            continue;
        }
        entries.push(AllowEntry {
            rule: rule.to_string(),
            file: file.to_string(),
            func: func.to_string(),
            reason: reason.to_string(),
            line: i + 1,
        });
    }
    (entries, errs)
}

// ---------------------------------------------------------------------------
// Source stripping (comments, strings, char literals → spaces)
// ---------------------------------------------------------------------------

/// Per-line metadata harvested from comments before they are blanked.
#[derive(Debug, Clone, Default)]
pub struct LineMeta {
    /// `lint:allow(rule): reason` markers on this line.
    pub allows: Vec<(String, String)>,
    /// The line carries a `SAFETY:` comment.
    pub safety: bool,
}

/// One scanned file: structure-preserving stripped source + comment facts.
pub struct Scan {
    /// Source with comment/string/char contents replaced by spaces
    /// (newlines kept, so byte offsets and line numbers are unchanged).
    pub code: String,
    /// Index by 0-based line.
    pub meta: Vec<LineMeta>,
    /// Byte offset of each line start (for offset → line lookups).
    pub line_starts: Vec<usize>,
    /// 0-based line of the first `#[cfg(test)]`; scanning stops there.
    pub cutoff_line: usize,
    /// Malformed `lint:allow` markers (missing reason / unknown rule).
    pub marker_errors: Vec<(usize, String)>,
}

impl Scan {
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(l) => l,
            Err(l) => l - 1,
        }
    }

    /// Is `rule` allowed at 1-based line `line` (marker on the line itself
    /// or the line directly above)?
    fn allowed_inline(&self, rule: &str, line0: usize) -> bool {
        let hit = |l: usize| {
            self.meta
                .get(l)
                .is_some_and(|m| m.allows.iter().any(|(r, _)| r == rule))
        };
        hit(line0) || (line0 > 0 && hit(line0 - 1))
    }

    /// Any `SAFETY:` comment within `span` lines above (or on) `line0`?
    fn safety_near(&self, line0: usize, span: usize) -> bool {
        (line0.saturating_sub(span)..=line0)
            .any(|l| self.meta.get(l).is_some_and(|m| m.safety))
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn parse_marker(comment: &str, line0: usize, meta: &mut [LineMeta], errs: &mut Vec<(usize, String)>) {
    if comment.contains("SAFETY:") {
        meta[line0].safety = true;
    }
    let Some(at) = comment.find("lint:allow(") else {
        return;
    };
    let rest = &comment[at + "lint:allow(".len()..];
    let Some(close) = rest.find(')') else {
        errs.push((line0, "unterminated lint:allow(..) marker".to_string()));
        return;
    };
    let rule = rest[..close].trim().to_string();
    if !RULE_IDS.contains(&rule.as_str()) {
        errs.push((line0, format!("lint:allow names unknown rule {rule:?}")));
        return;
    }
    let tail = rest[close + 1..].trim_start();
    let reason = tail.strip_prefix(':').map(str::trim).unwrap_or("");
    if reason.is_empty() {
        errs.push((
            line0,
            format!("lint:allow({rule}) needs a `: reason` — justify the exception"),
        ));
        return;
    }
    meta[line0].allows.push((rule, reason.to_string()));
}

/// Blank comments, string literals, and char literals, preserving layout.
pub fn strip(src: &str) -> Scan {
    let bytes = src.as_bytes();
    let mut out = bytes.to_vec();
    let n = bytes.len();

    let mut line_starts = vec![0usize];
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' {
            line_starts.push(i + 1);
        }
    }
    let nlines = line_starts.len();
    let mut meta = vec![LineMeta::default(); nlines];
    let mut marker_errors = Vec::new();
    let line_of = |off: usize| match line_starts.binary_search(&off) {
        Ok(l) => l,
        Err(l) => l - 1,
    };

    let blank = |out: &mut Vec<u8>, from: usize, to: usize| {
        for b in &mut out[from..to] {
            if *b != b'\n' {
                *b = b' ';
            }
        }
    };

    let mut i = 0usize;
    while i < n {
        let b = bytes[i];
        // Line comment.
        if b == b'/' && i + 1 < n && bytes[i + 1] == b'/' {
            let start = i;
            while i < n && bytes[i] != b'\n' {
                i += 1;
            }
            let text = &src[start..i];
            parse_marker(text, line_of(start), &mut meta, &mut marker_errors);
            blank(&mut out, start, i);
            continue;
        }
        // Block comment (nested).
        if b == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if bytes[i] == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if bytes[i] == b'*' && i + 1 < n && bytes[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            if src[start..i].contains("SAFETY:") {
                meta[line_of(start)].safety = true;
            }
            blank(&mut out, start, i);
            continue;
        }
        // Raw (and raw byte) strings: r"..", r#".."#, br#".."#.
        if (b == b'r' || b == b'b') && (i == 0 || !is_ident(bytes[i - 1])) {
            let mut j = i;
            if bytes[j] == b'b' && j + 1 < n && bytes[j + 1] == b'r' {
                j += 1;
            }
            if bytes[j] == b'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && bytes[k] == b'#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && bytes[k] == b'"' {
                    // Find the closing quote + hashes.
                    let mut e = k + 1;
                    'raw: while e < n {
                        if bytes[e] == b'"' {
                            let mut h = 0usize;
                            while e + 1 + h < n && bytes[e + 1 + h] == b'#' && h < hashes {
                                h += 1;
                            }
                            if h == hashes {
                                e += 1 + hashes;
                                break 'raw;
                            }
                        }
                        e += 1;
                    }
                    blank(&mut out, i, e);
                    i = e;
                    continue;
                }
            }
        }
        // Normal (and byte) strings.
        if b == b'"' || (b == b'b' && i + 1 < n && bytes[i + 1] == b'"' && !is_ident_prev(bytes, i))
        {
            let q = if b == b'"' { i } else { i + 1 };
            let mut e = q + 1;
            while e < n {
                if bytes[e] == b'\\' {
                    e += 2;
                    continue;
                }
                if bytes[e] == b'"' {
                    e += 1;
                    break;
                }
                e += 1;
            }
            let e = e.min(n);
            blank(&mut out, i, e);
            i = e;
            continue;
        }
        // Char literal vs lifetime.
        if b == b'\'' {
            if i + 1 < n && bytes[i + 1] == b'\\' {
                // '\n', '\u{..}', ...
                let mut e = i + 2;
                while e < n && bytes[e] != b'\'' {
                    e += 1;
                }
                let e = (e + 1).min(n);
                blank(&mut out, i, e);
                i = e;
                continue;
            }
            if i + 2 < n && bytes[i + 2] == b'\'' && bytes[i + 1] != b'\'' {
                blank(&mut out, i, i + 3);
                i += 3;
                continue;
            }
            // Lifetime: skip the tick + ident.
            i += 1;
            while i < n && is_ident(bytes[i]) {
                i += 1;
            }
            continue;
        }
        i += 1;
    }

    let cutoff_line = src
        .lines()
        .position(|l| l.trim_start().starts_with("#[cfg(test)]"))
        .unwrap_or(nlines);

    Scan {
        code: String::from_utf8_lossy(&out).into_owned(),
        meta,
        line_starts,
        cutoff_line,
        marker_errors,
    }
}

fn is_ident_prev(bytes: &[u8], i: usize) -> bool {
    i > 0 && is_ident(bytes[i - 1])
}

// ---------------------------------------------------------------------------
// Token / structure helpers over stripped source
// ---------------------------------------------------------------------------

/// Iterate identifiers as `(start, end)` byte ranges.
fn idents(code: &str) -> impl Iterator<Item = (usize, usize)> + '_ {
    let bytes = code.as_bytes();
    let n = bytes.len();
    let mut i = 0usize;
    std::iter::from_fn(move || {
        while i < n {
            if is_ident(bytes[i]) && (i == 0 || !is_ident(bytes[i - 1])) {
                let s = i;
                while i < n && is_ident(bytes[i]) {
                    i += 1;
                }
                return Some((s, i));
            }
            i += 1;
        }
        None
    })
}

fn next_nonspace(bytes: &[u8], mut i: usize) -> Option<u8> {
    while i < bytes.len() {
        let b = bytes[i];
        if b != b' ' && b != b'\n' && b != b'\r' && b != b'\t' {
            return Some(b);
        }
        i += 1;
    }
    None
}

/// A function definition found in stripped source.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    /// 0-based line of the `fn` keyword.
    pub line: usize,
    /// Body byte range `(open_brace, close_brace)`, if the fn has a body.
    pub body: Option<(usize, usize)>,
}

/// Extract every `fn name … { body }` (including nested) before `cutoff`.
pub fn extract_fns(scan: &Scan) -> Vec<FnSpan> {
    let code = &scan.code;
    let bytes = code.as_bytes();
    let n = bytes.len();
    let cutoff_off = scan
        .line_starts
        .get(scan.cutoff_line)
        .copied()
        .unwrap_or(n);
    let mut fns = Vec::new();
    for (s, e) in idents(code) {
        if s >= cutoff_off {
            break;
        }
        if &code[s..e] != "fn" {
            continue;
        }
        // Name (skip `fn(` function-pointer types).
        let mut j = e;
        while j < n && (bytes[j] as char).is_whitespace() {
            j += 1;
        }
        if j >= n || !is_ident(bytes[j]) {
            continue;
        }
        let ns = j;
        while j < n && is_ident(bytes[j]) {
            j += 1;
        }
        let name = code[ns..j].to_string();
        // Signature scan: body starts at the first `{` at paren/bracket
        // depth 0; a `;` there means a bodyless (trait) declaration.
        let mut paren = 0i32;
        let mut bracket = 0i32;
        let mut body = None;
        while j < n {
            match bytes[j] {
                b'(' => paren += 1,
                b')' => paren -= 1,
                b'[' => bracket += 1,
                b']' => bracket -= 1,
                b'{' if paren == 0 && bracket == 0 => {
                    // Match the body braces.
                    let open = j;
                    let mut depth = 1i32;
                    let mut k = j + 1;
                    while k < n && depth > 0 {
                        match bytes[k] {
                            b'{' => depth += 1,
                            b'}' => depth -= 1,
                            _ => {}
                        }
                        k += 1;
                    }
                    body = Some((open, k.saturating_sub(1)));
                    break;
                }
                b';' if paren == 0 && bracket == 0 => break,
                b'}' if paren == 0 && bracket == 0 => break,
                _ => {}
            }
            j += 1;
        }
        fns.push(FnSpan { name, line: scan.line_of(s), body });
    }
    fns
}

/// Callee names inside `span`: identifiers directly followed by `(`
/// (macros — ident followed by `!` — are not calls).
fn callees(code: &str, span: (usize, usize)) -> BTreeSet<String> {
    let bytes = code.as_bytes();
    let mut out = BTreeSet::new();
    for (s, e) in idents(&code[span.0..span.1]) {
        let (s, e) = (s + span.0, e + span.0);
        let name = &code[s..e];
        if KEYWORDS.contains(&name) || CALL_EDGE_EXCLUDED.contains(&name) {
            continue;
        }
        if next_nonspace(bytes, e) == Some(b'(') {
            out.insert(name.to_string());
        }
    }
    out
}

/// Byte spans of `for`/`while`/`loop` bodies before the cutoff.
fn loop_spans(scan: &Scan) -> Vec<(usize, usize)> {
    let code = &scan.code;
    let bytes = code.as_bytes();
    let n = bytes.len();
    let cutoff_off = scan
        .line_starts
        .get(scan.cutoff_line)
        .copied()
        .unwrap_or(n);
    let mut spans = Vec::new();
    for (s, e) in idents(code) {
        if s >= cutoff_off {
            break;
        }
        let kw = &code[s..e];
        if kw != "for" && kw != "while" && kw != "loop" {
            continue;
        }
        // Find the body `{` at paren/bracket depth 0 (loop headers don't
        // contain braces in this codebase).
        let mut paren = 0i32;
        let mut bracket = 0i32;
        let mut j = e;
        let mut open = None;
        while j < n {
            match bytes[j] {
                b'(' => paren += 1,
                b')' => paren -= 1,
                b'[' => bracket += 1,
                b']' => bracket -= 1,
                b'{' if paren == 0 && bracket == 0 => {
                    open = Some(j);
                    break;
                }
                b';' | b'}' if paren == 0 && bracket == 0 => break,
                _ => {}
            }
            j += 1;
        }
        let Some(open) = open else { continue };
        let mut depth = 1i32;
        let mut k = open + 1;
        while k < n && depth > 0 {
            match bytes[k] {
                b'{' => depth += 1,
                b'}' => depth -= 1,
                _ => {}
            }
            k += 1;
        }
        spans.push((open, k));
    }
    spans
}

/// All occurrences of `pat` in `code[span]`, as absolute byte offsets.
fn find_all(code: &str, span: (usize, usize), pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let hay = &code[span.0..span.1];
    let mut from = 0usize;
    while let Some(at) = hay[from..].find(pat) {
        out.push(span.0 + from + at);
        from += at + pat.len().max(1);
    }
    out
}

// ---------------------------------------------------------------------------
// The lint pass proper
// ---------------------------------------------------------------------------

/// Lint report: what was checked, what failed, what was excused.
pub struct Report {
    pub violations: Vec<Violation>,
    pub files: usize,
    /// Names of functions classified hot (diagnostics / self-tests).
    pub hot_fns: BTreeSet<String>,
    /// Count of findings suppressed by markers or allowlist entries.
    pub allowed: usize,
}

struct FileCtx {
    rel: String,
    display: String,
    scan: Scan,
    fns: Vec<FnSpan>,
}

impl FileCtx {
    /// Innermost function containing `offset` (smallest enclosing body).
    fn enclosing_fn(&self, offset: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.body.is_some_and(|(a, b)| a <= offset && offset < b))
            .min_by_key(|f| f.body.map(|(a, b)| b - a).unwrap_or(usize::MAX))
    }
}

/// Lint a set of `(path_relative_to_rust_src, source)` files against an
/// allowlist. This is the engine behind both the real tree walk and the
/// fixture self-tests.
pub fn lint_files(files: &[(String, String)], allowlist: &[AllowEntry]) -> Report {
    let mut ctxs = Vec::new();
    for (rel, src) in files {
        let scan = strip(src);
        let fns = extract_fns(&scan);
        ctxs.push(FileCtx {
            rel: rel.clone(),
            display: format!("rust/src/{rel}"),
            scan,
            fns,
        });
    }

    let mut violations = Vec::new();
    let mut allowed = 0usize;
    let mut used_entries: BTreeSet<usize> = BTreeSet::new();

    // Marker syntax errors are violations in their own right.
    for ctx in &ctxs {
        for (line0, msg) in &ctx.scan.marker_errors {
            violations.push(Violation {
                path: ctx.display.clone(),
                line: line0 + 1,
                rule: "lint-allow",
                msg: msg.clone(),
            });
        }
    }

    // `emit` routes one finding through the marker + allowlist machinery.
    let mut emit = |ctx: &FileCtx,
                    offset: usize,
                    rule: &'static str,
                    msg: String,
                    violations: &mut Vec<Violation>,
                    allowed: &mut usize,
                    used: &mut BTreeSet<usize>| {
        let line0 = ctx.scan.line_of(offset);
        if line0 >= ctx.scan.cutoff_line {
            return;
        }
        if ctx.scan.allowed_inline(rule, line0) {
            *allowed += 1;
            return;
        }
        let func = ctx.enclosing_fn(offset).map(|f| f.name.clone());
        if let Some((idx, _)) = allowlist.iter().enumerate().find(|(_, a)| {
            a.rule == rule
                && a.file == ctx.rel
                && (a.func == "*" || Some(&a.func) == func.as_ref())
        }) {
            used.insert(idx);
            *allowed += 1;
            return;
        }
        violations.push(Violation {
            path: ctx.display.clone(),
            line: line0 + 1,
            rule,
            msg,
        });
    };

    // ---- per-file token rules ------------------------------------------
    for ctx in &ctxs {
        let det = in_deterministic_module(&ctx.rel);
        let code = &ctx.scan.code;
        let whole = (0usize, code.len());
        for (s, e) in idents(code) {
            let name = &code[s..e];
            match name {
                "HashMap" | "HashSet" if det => emit(
                    ctx,
                    s,
                    "nondet-collection",
                    format!(
                        "{name} in a deterministic module — iteration order is \
                         process-random; use BTreeMap/BTreeSet (or justify)"
                    ),
                    &mut violations,
                    &mut allowed,
                    &mut used_entries,
                ),
                "Instant" | "SystemTime" if det => emit(
                    ctx,
                    s,
                    "wall-clock",
                    format!(
                        "std::time::{name} in a deterministic module — results must \
                         not depend on time; observability timing goes through \
                         util::Stopwatch"
                    ),
                    &mut violations,
                    &mut allowed,
                    &mut used_entries,
                ),
                "thread_rng" | "ThreadRng" | "from_entropy" => emit(
                    ctx,
                    s,
                    "ambient-rng",
                    format!("{name}: ambient randomness — every RNG must be util::Rng with an explicit seed"),
                    &mut violations,
                    &mut allowed,
                    &mut used_entries,
                ),
                "available_parallelism" if det => emit(
                    ctx,
                    s,
                    "ambient-parallelism",
                    "available_parallelism in a deterministic module — results must \
                     not depend on the host's core count (the kernel thread budget \
                     lives in backend::native::tensor)"
                        .to_string(),
                    &mut violations,
                    &mut allowed,
                    &mut used_entries,
                ),
                "unsafe" => {
                    let line0 = ctx.scan.line_of(s);
                    if !ctx.scan.safety_near(line0, 5) {
                        emit(
                            ctx,
                            s,
                            "unsafe-needs-safety",
                            "unsafe without a `// SAFETY:` comment within 5 lines above"
                                .to_string(),
                            &mut violations,
                            &mut allowed,
                            &mut used_entries,
                        );
                    }
                }
                _ => {}
            }
        }

        // lock-in-loop: `.lock(` lexically inside a loop body.
        if det || in_hot_universe(&ctx.rel) {
            let loops = loop_spans(&ctx.scan);
            for off in find_all(code, whole, ".lock(") {
                if loops.iter().any(|&(a, b)| a <= off && off < b) {
                    emit(
                        ctx,
                        off,
                        "lock-in-loop",
                        "Mutex lock inside a loop — per-step locking must be \
                         justified (bounded critical section, barrier-ordered)"
                            .to_string(),
                        &mut violations,
                        &mut allowed,
                        &mut used_entries,
                    );
                }
            }
        }
    }

    // ---- hot-path reachability + alloc rule ----------------------------
    let mut by_name: BTreeMap<&str, Vec<(usize, usize)>> = BTreeMap::new();
    for (ci, ctx) in ctxs.iter().enumerate() {
        if !in_hot_universe(&ctx.rel) {
            continue;
        }
        for (fi, f) in ctx.fns.iter().enumerate() {
            if f.body.is_some() {
                by_name.entry(f.name.as_str()).or_default().push((ci, fi));
            }
        }
    }
    let mut hot: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut queue: Vec<(usize, usize)> = Vec::new();
    for root in HOT_ROOTS {
        for &site in by_name.get(root).map(Vec::as_slice).unwrap_or(&[]) {
            if hot.insert(site) {
                queue.push(site);
            }
        }
    }
    while let Some((ci, fi)) = queue.pop() {
        let ctx = &ctxs[ci];
        let Some(body) = ctx.fns[fi].body else { continue };
        for name in callees(&ctx.scan.code, body) {
            for &site in by_name.get(name.as_str()).map(Vec::as_slice).unwrap_or(&[]) {
                if hot.insert(site) {
                    queue.push(site);
                }
            }
        }
    }
    let mut hot_fns = BTreeSet::new();
    for &(ci, fi) in &hot {
        let ctx = &ctxs[ci];
        let f = &ctxs[ci].fns[fi];
        hot_fns.insert(format!("{}::{}", ctx.rel, f.name));
        let Some(body) = f.body else { continue };
        for pat in ALLOC_PATTERNS {
            for off in find_all(&ctx.scan.code, body, pat) {
                emit(
                    ctx,
                    off,
                    "hot-path-alloc",
                    format!(
                        "`{}` in `{}` (reachable from {:?}) — the warm train step \
                         must not allocate; draw from the Workspace arena",
                        pat.trim_end_matches('('),
                        f.name,
                        HOT_ROOTS
                    ),
                    &mut violations,
                    &mut allowed,
                    &mut used_entries,
                );
            }
        }
    }

    // ---- stale allowlist entries ---------------------------------------
    for (idx, entry) in allowlist.iter().enumerate() {
        if !used_entries.contains(&idx) {
            violations.push(Violation {
                path: "rust/xtask/allowlist.txt".to_string(),
                line: entry.line,
                rule: "allowlist",
                msg: format!(
                    "stale entry ({} | {} | {}): nothing matches it any more — \
                     delete it so the allowlist only shrinks",
                    entry.rule, entry.file, entry.func
                ),
            });
        }
    }

    violations.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    Report { violations, files: ctxs.len(), hot_fns, allowed }
}

/// Walk `<repo>/rust/src`, collecting `(rel, source)` pairs sorted by path.
pub fn collect_tree(repo_root: &Path) -> Result<Vec<(String, String)>, String> {
    let src_root = repo_root.join("rust/src");
    let mut files = Vec::new();
    let mut stack = vec![src_root.clone()];
    while let Some(dir) = stack.pop() {
        let entries = std::fs::read_dir(&dir)
            .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read_dir entry: {e}"))?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                let rel = path
                    .strip_prefix(&src_root)
                    .map_err(|e| format!("strip_prefix: {e}"))?
                    .to_string_lossy()
                    .replace('\\', "/");
                let src = std::fs::read_to_string(&path)
                    .map_err(|e| format!("read {}: {e}", path.display()))?;
                files.push((rel, src));
            }
        }
    }
    files.sort();
    Ok(files)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_one(rel: &str, src: &str) -> Report {
        lint_files(&[(rel.to_string(), src.to_string())], &[])
    }

    fn rules_of(r: &Report) -> Vec<&'static str> {
        r.violations.iter().map(|v| v.rule).collect()
    }

    // ---- fixture snippets: each must fail its lint ---------------------

    #[test]
    fn fixture_nondet_collection_fails() {
        let r = run_one(
            "sep/fixture.rs",
            include_str!("../fixtures/fail_nondet_collection.rs"),
        );
        assert!(rules_of(&r).contains(&"nondet-collection"), "{:?}", r.violations);
    }

    #[test]
    fn fixture_wall_clock_fails() {
        let r = run_one(
            "graph/fixture.rs",
            include_str!("../fixtures/fail_wall_clock.rs"),
        );
        assert!(rules_of(&r).contains(&"wall-clock"), "{:?}", r.violations);
    }

    #[test]
    fn fixture_ambient_rng_fails_everywhere() {
        // Not a deterministic module on purpose: the rng rule is global.
        let r = run_one(
            "serve/fixture.rs",
            include_str!("../fixtures/fail_ambient_rng.rs"),
        );
        assert!(rules_of(&r).contains(&"ambient-rng"), "{:?}", r.violations);
    }

    #[test]
    fn fixture_ambient_parallelism_fails() {
        let r = run_one(
            "coordinator/trainer.rs",
            include_str!("../fixtures/fail_ambient_parallelism.rs"),
        );
        assert!(rules_of(&r).contains(&"ambient-parallelism"), "{:?}", r.violations);
    }

    #[test]
    fn fixture_hot_alloc_fails_transitively() {
        let r = run_one(
            "backend/native/fixture.rs",
            include_str!("../fixtures/fail_hot_alloc.rs"),
        );
        // The alloc is two calls below `step`; reachability must find it.
        assert!(rules_of(&r).contains(&"hot-path-alloc"), "{:?}", r.violations);
        assert!(r.hot_fns.iter().any(|f| f.ends_with("::helper_two")));
    }

    #[test]
    fn fixture_unsafe_without_safety_fails() {
        let r = run_one(
            "mem/fixture.rs",
            include_str!("../fixtures/fail_unsafe_no_safety.rs"),
        );
        assert!(rules_of(&r).contains(&"unsafe-needs-safety"), "{:?}", r.violations);
    }

    #[test]
    fn fixture_lock_in_loop_fails() {
        let r = run_one(
            "coordinator/batcher.rs",
            include_str!("../fixtures/fail_lock_in_loop.rs"),
        );
        assert!(rules_of(&r).contains(&"lock-in-loop"), "{:?}", r.violations);
    }

    #[test]
    fn fixture_empty_allow_reason_fails() {
        let r = run_one(
            "sep/fixture.rs",
            include_str!("../fixtures/fail_empty_allow_reason.rs"),
        );
        assert!(rules_of(&r).contains(&"lint-allow"), "{:?}", r.violations);
    }

    #[test]
    fn fixture_clean_passes() {
        let r = run_one(
            "sep/fixture.rs",
            include_str!("../fixtures/pass_clean.rs"),
        );
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        // The justified marker counted as an excused finding.
        assert!(r.allowed > 0);
    }

    // ---- machinery ------------------------------------------------------

    #[test]
    fn inline_marker_suppresses_with_reason() {
        let src = "use std::collections::HashMap; // lint:allow(nondet-collection): lookup-only\n";
        let r = run_one("sep/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(r.allowed, 1);
    }

    #[test]
    fn marker_on_line_above_suppresses() {
        let src = "// lint:allow(wall-clock): fixture timing\nuse std::time::Instant;\n";
        let r = run_one("data/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn tokens_in_strings_and_comments_are_ignored() {
        let src = "// HashMap Instant thread_rng\nconst DOC: &str = \"HashMap Vec::new()\";\n";
        let r = run_one("sep/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn code_after_cfg_test_is_exempt() {
        let src = "pub fn ok() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    fn f() { let _: HashMap<u8, u8> = HashMap::new(); }\n}\n";
        let r = run_one("sep/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn allowlist_entry_suppresses_and_stale_entry_fails() {
        let (entries, errs) = parse_allowlist(
            "nondet-collection | sep/x.rs | lookup | membership-only, never iterated\n\
             wall-clock | graph/y.rs | * | stale grant\n",
            "rust/xtask/allowlist.txt",
        );
        assert!(errs.is_empty(), "{errs:?}");
        let files = vec![(
            "sep/x.rs".to_string(),
            "fn lookup() { let _ = std::collections::HashMap::<u8, u8>::new(); }\n".to_string(),
        )];
        let r = lint_files(&files, &entries);
        // The HashMap is excused; the unused wall-clock grant is stale.
        let rules = rules_of(&r);
        assert!(!rules.contains(&"nondet-collection"), "{:?}", r.violations);
        assert!(rules.contains(&"allowlist"), "{:?}", r.violations);
    }

    #[test]
    fn allowlist_rejects_unknown_rule_and_empty_reason() {
        let (_, errs) = parse_allowlist(
            "no-such-rule | a.rs | * | x\nwall-clock | a.rs | * |\n",
            "rust/xtask/allowlist.txt",
        );
        assert_eq!(errs.len(), 2, "{errs:?}");
    }

    #[test]
    fn hot_path_ignores_unreachable_allocs() {
        let src = "fn cold() -> Vec<u8> { Vec::new() }\nfn step() { let x = 1; let _ = x; }\n";
        let r = run_one("backend/native/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn lifetimes_do_not_confuse_the_stripper() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nstruct S<'b> { v: &'b [u8] }\n";
        let scan = strip(src);
        assert!(scan.code.contains("fn f"), "{}", scan.code);
        assert_eq!(extract_fns(&scan).len(), 1);
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "const X: &str = r#\"HashMap \" inner\"#;\nfn g() {}\n";
        let r = run_one("sep/x.rs", src);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        assert_eq!(extract_fns(&strip(src)).len(), 1);
    }
}
