//! `cargo xtask` — repo automation. The only subcommand today is `lint`,
//! the invariant pass described in `lint.rs` / docs/INVARIANTS.md.
//!
//! Usage:
//!   cargo xtask lint [--root <repo-root>] [--verbose]
//!
//! Exit status: 0 when the tree is clean, 1 when any violation (or stale
//! allowlist entry) is found, 2 on usage / IO errors.

#![cfg_attr(test, allow(clippy::unwrap_used))]

mod lint;

use std::path::PathBuf;
use std::process::ExitCode;

fn repo_root_default() -> PathBuf {
    // rust/xtask/ -> repo root is two levels up from this crate's manifest.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..")
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut it = args.iter();
    let Some(cmd) = it.next() else {
        eprintln!("usage: cargo xtask lint [--root <repo-root>] [--verbose]");
        return ExitCode::from(2);
    };
    if cmd != "lint" {
        eprintln!("unknown subcommand {cmd:?} (expected `lint`)");
        return ExitCode::from(2);
    }
    let mut root = repo_root_default();
    let mut verbose = false;
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--root" => match it.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("--root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--verbose" | "-v" => verbose = true,
            other => {
                eprintln!("unknown flag {other:?}");
                return ExitCode::from(2);
            }
        }
    }

    let allowlist_path = root.join("rust/xtask/allowlist.txt");
    let allowlist_text = match std::fs::read_to_string(&allowlist_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {}: {e}", allowlist_path.display());
            return ExitCode::from(2);
        }
    };
    let (entries, mut allow_errs) =
        lint::parse_allowlist(&allowlist_text, "rust/xtask/allowlist.txt");

    let files = match lint::collect_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::from(2);
        }
    };

    let mut report = lint::lint_files(&files, &entries);
    report.violations.append(&mut allow_errs);
    report.violations.sort_by(|a, b| (a.path.clone(), a.line).cmp(&(b.path.clone(), b.line)));

    if verbose {
        eprintln!("hot functions ({}):", report.hot_fns.len());
        for f in &report.hot_fns {
            eprintln!("  {f}");
        }
    }
    for v in &report.violations {
        println!("{v}");
    }
    if report.violations.is_empty() {
        println!(
            "speed-lint: {} files clean ({} hot fns, {} findings excused)",
            report.files,
            report.hot_fns.len(),
            report.allowed
        );
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "speed-lint: {} violation(s) — see docs/INVARIANTS.md for the rules \
             and rust/xtask/allowlist.txt for the escape hatch",
            report.violations.len()
        );
        ExitCode::FAILURE
    }
}
