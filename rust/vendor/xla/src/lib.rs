//! Offline stub of the `xla` crate (xla-rs) API surface used by
//! `speed_tig::runtime`.
//!
//! Purpose: `cargo build --features pjrt` (and clippy over all features)
//! must compile in environments that have no XLA/PJRT native libraries.
//! Every constructor returns [`Error::Unavailable`], so the `pjrt` backend
//! fails loudly and immediately at `Runtime::load` time with instructions,
//! never mid-training. To run the real HLO artifacts, point the `xla` path
//! dependency in `rust/Cargo.toml` at the actual xla-rs crate.

use std::path::Path;

/// Stub error: always "PJRT unavailable".
#[derive(Debug)]
pub enum Error {
    Unavailable(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: this build uses the vendored xla API stub; replace \
                 rust/vendor/xla with the real xla-rs crate (plus an XLA \
                 PJRT plugin) to execute AOT artifacts"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error::Unavailable(what.to_string()))
}

/// Element types of literals we marshal (only F32 is used by speed_tig).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// Host-side tensor stub.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Device-side buffer stub.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module stub.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation stub.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client stub.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}
