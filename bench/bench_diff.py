#!/usr/bin/env python3
"""Perf-regression gate: compare BENCH_native.json against the committed
baseline (bench/BASELINE_native.json) and fail on per-step slowdowns.

Usage:
    python3 bench/bench_diff.py [--baseline PATH] [--current PATH]
                                [--threshold PCT]

Exit codes:
    0  no gated metric regressed by more than --threshold percent
       (also: baseline is marked "provisional": true -- table printed,
       regressions reported as warnings only, so the gate can be armed
       by re-recording the baseline on the reference machine)
    1  at least one gated per-step metric regressed past the threshold
    2  missing/unreadable input, or the two files are not comparable
       (different batch/dim/scale shapes)

Gated metrics are the per-model step timings (train/eval x
serial/parallel); per-kernel rows are printed for diagnosis but do not
gate, since tiny kernels are noisier than whole steps. When both files
carry a `calib_ns` meta field (a deterministic f64 FMA loop timed by
bench_train_step), the baseline is rescaled by calib_cur/calib_base
before comparison so a baseline recorded on different hardware still
yields a meaningful -- if approximate -- delta.
"""

import argparse
import json
import sys

STEP_KEYS = ("train_serial_ns", "train_parallel_ns", "eval_serial_ns", "eval_parallel_ns")
KERNEL_KEYS = ("serial_ns", "parallel_ns")


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench-diff: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def fmt_row(name, key, base, cur, pct, flag):
    return f"  {name:<28} {key:<20} {base:>12.1f} {cur:>12.1f} {pct:>+8.1f}%  {flag}"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="bench/BASELINE_native.json")
    ap.add_argument("--current", default="BENCH_native.json")
    ap.add_argument("--threshold", type=float, default=15.0,
                    help="max allowed per-step slowdown in percent (default 15)")
    args = ap.parse_args()

    base = load(args.baseline)
    cur = load(args.current)

    for key in ("batch", "dim"):
        if base.get(key) != cur.get(key):
            print(
                f"bench-diff: not comparable: {key} differs "
                f"(baseline {base.get(key)}, current {cur.get(key)})",
                file=sys.stderr,
            )
            sys.exit(2)
    if "scale" in base and "scale" in cur and base["scale"] != cur["scale"]:
        print(
            f"bench-diff: not comparable: bench scale differs "
            f"(baseline {base['scale']}, current {cur['scale']})",
            file=sys.stderr,
        )
        sys.exit(2)

    provisional = bool(base.get("provisional", False))
    ratio = 1.0
    if base.get("calib_ns") and cur.get("calib_ns"):
        ratio = cur["calib_ns"] / base["calib_ns"]

    print(f"bench-diff: baseline {args.baseline} vs current {args.current}")
    print(f"  machine-speed rescale (calib_cur/calib_base): x{ratio:.3f}")
    if base.get("rustc") != cur.get("rustc"):
        print(
            f"  note: rustc differs (baseline {base.get('rustc')!r}, "
            f"current {cur.get('rustc')!r})"
        )
    header = f"  {'case':<28} {'metric':<20} {'base(ns)':>12} {'cur(ns)':>12} {'delta':>9}"
    print(header)
    print("  " + "-" * (len(header) - 2))

    regressions = []
    for section, keys, gated in (("steps", STEP_KEYS, True), ("kernels", KERNEL_KEYS, False)):
        b_sec, c_sec = base.get(section, {}), cur.get(section, {})
        for name in sorted(b_sec):
            if name not in c_sec:
                print(f"  {name:<28} missing from current run")
                continue
            for key in keys:
                if key not in b_sec[name] or key not in c_sec[name]:
                    continue
                scaled = b_sec[name][key] * ratio
                pct = (c_sec[name][key] - scaled) / scaled * 100.0
                slow = pct > args.threshold
                flag = ""
                if slow:
                    flag = "<< REGRESSION" if gated else "(kernel; not gated)"
                print(fmt_row(name, key, scaled, c_sec[name][key], pct, flag))
                if slow and gated:
                    regressions.append((name, key, pct))

    if regressions:
        print()
        for name, key, pct in regressions:
            print(f"bench-diff: {name}.{key} regressed {pct:+.1f}% "
                  f"(threshold {args.threshold:.1f}%)")
        if provisional:
            print("bench-diff: baseline is provisional -- reporting only, not failing.")
            print("bench-diff: arm the gate with `make bench-baseline` on the "
                  "reference machine.")
            sys.exit(0)
        sys.exit(1)
    print("bench-diff: OK -- no gated metric regressed past "
          f"{args.threshold:.1f}%")
    sys.exit(0)


if __name__ == "__main__":
    main()
